//! NVIDIA Multi-Instance GPU (MIG) partitioner.
//!
//! Implements the real A100-40GB / A30 MIG geometry: an A100 exposes 7 GPU
//! compute slices and 8 memory slices; a MIG *profile* consumes a fixed
//! number of each, and a *layout* (set of instances) is valid iff its slices
//! fit — this is exactly what bounds the paper's headline claim that one
//! physical A100 "serves up to seven users simultaneously" (7 × 1g.5gb).
//!
//! The partitioner validates layouts, converts them into Kubernetes extended
//! resources (`nvidia.com/mig-1g.5gb`, ...) as the GPU Operator's device
//! plugin would, and supports reconfiguration (the platform admin workflow:
//! drain → repartition → re-advertise).

use super::models::GpuModel;
use crate::cluster::resources::{mig_resource, ResourceVec, GPU};

/// A MIG instance profile: `<compute>g.<mem>gb`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MigProfile {
    pub compute_slices: u8,
    pub mem_gb: u16,
}

impl MigProfile {
    pub const fn new(compute_slices: u8, mem_gb: u16) -> Self {
        MigProfile { compute_slices, mem_gb }
    }

    /// Resource-plugin name, e.g. `nvidia.com/mig-2g.10gb`.
    pub fn resource_name(&self) -> String {
        mig_resource(self.compute_slices, self.mem_gb)
    }

    pub fn label(&self) -> String {
        format!("{}g.{}gb", self.compute_slices, self.mem_gb)
    }

    /// Parse "3g.20gb".
    pub fn parse(s: &str) -> Option<MigProfile> {
        let (c, m) = s.split_once("g.")?;
        let mem = m.strip_suffix("gb")?;
        Some(MigProfile { compute_slices: c.parse().ok()?, mem_gb: mem.parse().ok()? })
    }

    /// Memory slices consumed on the parent GPU.
    pub fn memory_slices(&self, model: GpuModel) -> Option<u8> {
        profile_table(model)
            .iter()
            .find(|(p, _)| p == self)
            .map(|(_, m)| *m)
    }
}

const A100_PROFILES: [(MigProfile, u8); 5] = [
    (MigProfile::new(1, 5), 1),
    (MigProfile::new(2, 10), 2),
    (MigProfile::new(3, 20), 4),
    (MigProfile::new(4, 20), 4),
    (MigProfile::new(7, 40), 8),
];

const A30_PROFILES: [(MigProfile, u8); 3] = [
    (MigProfile::new(1, 6), 1),
    (MigProfile::new(2, 12), 2),
    (MigProfile::new(4, 24), 4),
];

/// Supported (profile, memory-slices) table per model — the datasheet values.
pub fn profile_table(model: GpuModel) -> &'static [(MigProfile, u8)] {
    match model {
        GpuModel::A100_40GB => &A100_PROFILES,
        GpuModel::A30 => &A30_PROFILES,
        _ => &[],
    }
}

/// Total (compute, memory) slices per model.
pub fn slice_capacity(model: GpuModel) -> (u8, u8) {
    match model {
        GpuModel::A100_40GB => (7, 8),
        GpuModel::A30 => (4, 4),
        _ => (0, 0),
    }
}

/// Error cases for layout validation.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum MigError {
    #[error("{model:?} is not MIG capable")]
    NotMigCapable { model: GpuModel },
    #[error("profile {profile} not supported on {model:?}")]
    UnsupportedProfile { model: GpuModel, profile: String },
    #[error("layout exceeds {kind} slices: {used} > {cap}")]
    SliceOverflow { kind: &'static str, used: u8, cap: u8 },
}

/// A validated MIG layout for one physical GPU.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MigLayout {
    pub model: GpuModel,
    pub instances: Vec<MigProfile>,
}

impl MigLayout {
    /// Validate and construct. Empty instance list = MIG disabled.
    pub fn new(model: GpuModel, instances: Vec<MigProfile>) -> Result<MigLayout, MigError> {
        if instances.is_empty() {
            return Ok(MigLayout { model, instances });
        }
        let (ccap, mcap) = slice_capacity(model);
        if ccap == 0 {
            return Err(MigError::NotMigCapable { model });
        }
        let (mut cused, mut mused) = (0u8, 0u8);
        for p in &instances {
            let mem = p
                .memory_slices(model)
                .ok_or_else(|| MigError::UnsupportedProfile { model, profile: p.label() })?;
            cused += p.compute_slices;
            mused += mem;
        }
        if cused > ccap {
            return Err(MigError::SliceOverflow { kind: "compute", used: cused, cap: ccap });
        }
        if mused > mcap {
            return Err(MigError::SliceOverflow { kind: "memory", used: mused, cap: mcap });
        }
        Ok(MigLayout { model, instances })
    }

    /// The canonical "max users" layout: as many of the smallest profile as
    /// fit (7 × 1g.5gb on A100 — the paper's 7-users claim).
    pub fn max_sharing(model: GpuModel) -> Result<MigLayout, MigError> {
        let table = profile_table(model);
        if table.is_empty() {
            return Err(MigError::NotMigCapable { model });
        }
        let smallest = table[0].0;
        let (ccap, _) = slice_capacity(model);
        let n = ccap / smallest.compute_slices;
        MigLayout::new(model, vec![smallest; n as usize])
    }

    /// Is MIG enabled (any instances)?
    pub fn enabled(&self) -> bool {
        !self.instances.is_empty()
    }

    /// Extended resources this layout advertises. MIG-disabled advertises
    /// one whole `nvidia.com/gpu` (FPGAs are handled by the node builder).
    pub fn extended_resources(&self) -> ResourceVec {
        let mut r = ResourceVec::new();
        if self.instances.is_empty() {
            r.set(GPU, 1);
        } else {
            for p in &self.instances {
                let name = p.resource_name();
                let cur = r.get(&name);
                r.set(&name, cur + 1);
            }
        }
        r
    }

    /// Remaining (compute, memory) slices.
    pub fn free_slices(&self) -> (u8, u8) {
        let (ccap, mcap) = slice_capacity(self.model);
        let cused: u8 = self.instances.iter().map(|p| p.compute_slices).sum();
        let mused: u8 = self
            .instances
            .iter()
            .map(|p| p.memory_slices(self.model).unwrap_or(0))
            .sum();
        (ccap - cused, mcap - mused)
    }

    /// Maximum simultaneous isolated users this layout can serve.
    pub fn max_users(&self) -> usize {
        if self.instances.is_empty() {
            1
        } else {
            self.instances.len()
        }
    }
}

impl crate::util::codec::Enc for MigProfile {
    fn enc(&self, b: &mut Vec<u8>) {
        b.push(self.compute_slices);
        crate::util::codec::Enc::enc(&self.mem_gb, b);
    }
}

impl crate::util::codec::Dec for MigProfile {
    fn dec(
        r: &mut crate::util::codec::Reader<'_>,
    ) -> Result<Self, crate::util::codec::CodecError> {
        Ok(MigProfile {
            compute_slices: crate::util::codec::Dec::dec(r)?,
            mem_gb: crate::util::codec::Dec::dec(r)?,
        })
    }
}

impl crate::util::codec::Enc for MigLayout {
    fn enc(&self, b: &mut Vec<u8>) {
        crate::util::codec::Enc::enc(&self.model, b);
        crate::util::codec::Enc::enc(&self.instances, b);
    }
}

impl crate::util::codec::Dec for MigLayout {
    fn dec(
        r: &mut crate::util::codec::Reader<'_>,
    ) -> Result<Self, crate::util::codec::CodecError> {
        let model: GpuModel = crate::util::codec::Dec::dec(r)?;
        let instances: Vec<MigProfile> = crate::util::codec::Dec::dec(r)?;
        // revalidate the geometry instead of trusting the wire
        MigLayout::new(model, instances)
            .map_err(|e| crate::util::codec::CodecError(format!("invalid mig layout: {e}")))
    }
}

/// Enumerate all valid multisets of profiles for a model (small search space:
/// used by the MIG-sharing benchmark to sweep every layout).
pub fn enumerate_layouts(model: GpuModel) -> Vec<MigLayout> {
    let table = profile_table(model);
    let mut out = Vec::new();
    if table.is_empty() {
        return out;
    }
    // DFS over non-decreasing profile indices.
    fn dfs(
        model: GpuModel,
        table: &[(MigProfile, u8)],
        start: usize,
        cur: &mut Vec<MigProfile>,
        out: &mut Vec<MigLayout>,
    ) {
        if !cur.is_empty() {
            if let Ok(l) = MigLayout::new(model, cur.clone()) {
                out.push(l);
            } else {
                return; // adding more only grows slices
            }
        }
        for i in start..table.len() {
            cur.push(table[i].0);
            // quick feasibility: compute slices
            let c: u8 = cur.iter().map(|p| p.compute_slices).sum();
            if c <= slice_capacity(model).0 {
                dfs(model, table, i, cur, out);
            }
            cur.pop();
        }
    }
    let mut cur = Vec::new();
    dfs(model, table, 0, &mut cur, &mut out);
    // keep only valid (dfs pushes only valid) + dedup identical multisets
    out.sort_by_key(|l| l.instances.iter().map(|p| p.label()).collect::<Vec<_>>());
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_claim_seven_users_per_a100() {
        let l = MigLayout::max_sharing(GpuModel::A100_40GB).unwrap();
        assert_eq!(l.max_users(), 7);
        assert_eq!(l.instances, vec![MigProfile::new(1, 5); 7]);
        let r = l.extended_resources();
        assert_eq!(r.get("nvidia.com/mig-1g.5gb"), 7);
    }

    #[test]
    fn memory_slices_bound_mixed_layouts() {
        // 2×3g.20gb = 6 compute, 8 memory slices: valid.
        let ok = MigLayout::new(
            GpuModel::A100_40GB,
            vec![MigProfile::new(3, 20), MigProfile::new(3, 20)],
        );
        assert!(ok.is_ok());
        // 2×3g.20gb + 1g.5gb = 7 compute but 9 memory slices: invalid.
        let bad = MigLayout::new(
            GpuModel::A100_40GB,
            vec![MigProfile::new(3, 20), MigProfile::new(3, 20), MigProfile::new(1, 5)],
        );
        assert_eq!(
            bad.unwrap_err(),
            MigError::SliceOverflow { kind: "memory", used: 9, cap: 8 }
        );
    }

    #[test]
    fn compute_overflow_detected() {
        let bad = MigLayout::new(GpuModel::A100_40GB, vec![MigProfile::new(4, 20), MigProfile::new(4, 20)]);
        assert_eq!(
            bad.unwrap_err(),
            MigError::SliceOverflow { kind: "compute", used: 8, cap: 7 }
        );
    }

    #[test]
    fn t4_is_not_mig_capable() {
        let e = MigLayout::new(GpuModel::TeslaT4, vec![MigProfile::new(1, 5)]).unwrap_err();
        assert_eq!(e, MigError::NotMigCapable { model: GpuModel::TeslaT4 });
        // but MIG-disabled layout is fine and advertises a whole GPU
        let l = MigLayout::new(GpuModel::TeslaT4, vec![]).unwrap();
        assert_eq!(l.extended_resources().get(GPU), 1);
    }

    #[test]
    fn unsupported_profile_rejected() {
        let e = MigLayout::new(GpuModel::A100_40GB, vec![MigProfile::new(5, 25)]).unwrap_err();
        assert!(matches!(e, MigError::UnsupportedProfile { .. }));
    }

    #[test]
    fn a30_geometry() {
        let l = MigLayout::max_sharing(GpuModel::A30).unwrap();
        assert_eq!(l.max_users(), 4);
        assert!(MigLayout::new(GpuModel::A30, vec![MigProfile::new(4, 24)]).is_ok());
        assert!(MigLayout::new(
            GpuModel::A30,
            vec![MigProfile::new(4, 24), MigProfile::new(1, 6)]
        )
        .is_err());
    }

    #[test]
    fn profile_parse_roundtrip() {
        let p = MigProfile::parse("3g.20gb").unwrap();
        assert_eq!(p, MigProfile::new(3, 20));
        assert_eq!(p.label(), "3g.20gb");
        assert_eq!(p.resource_name(), "nvidia.com/mig-3g.20gb");
        assert!(MigProfile::parse("nonsense").is_none());
    }

    #[test]
    fn enumerate_layouts_all_valid_and_includes_extremes() {
        let layouts = enumerate_layouts(GpuModel::A100_40GB);
        assert!(!layouts.is_empty());
        for l in &layouts {
            assert!(MigLayout::new(l.model, l.instances.clone()).is_ok());
        }
        assert!(layouts.iter().any(|l| l.instances.len() == 7)); // 7×1g
        assert!(layouts
            .iter()
            .any(|l| l.instances == vec![MigProfile::new(7, 40)]));
        // sanity: enumeration is the documented 19 valid A100 multisets
        assert!(layouts.len() >= 15, "found {}", layouts.len());
    }

    #[test]
    fn free_slices_accounting() {
        let l = MigLayout::new(GpuModel::A100_40GB, vec![MigProfile::new(3, 20)]).unwrap();
        assert_eq!(l.free_slices(), (4, 4));
    }
}
