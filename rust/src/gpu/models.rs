//! Accelerator catalogue — the exact hardware the paper's §2 inventory lists.
//!
//! Specs (memory, peak FP32/FP16 throughput) are from the public NVIDIA /
//! AMD-Xilinx datasheets; they feed the DCGM-style telemetry simulator and
//! the job cost model (simulated execution time = FLOPs / effective rate).

/// NVIDIA GPU / AMD-Xilinx FPGA models deployed on the AI_INFN servers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuModel {
    /// NVIDIA Tesla T4 (Server 1) — 16 GB, no MIG.
    TeslaT4,
    /// NVIDIA Quadro RTX 5000 (Servers 1 & 4) — 16 GB, no MIG.
    Rtx5000,
    /// NVIDIA A100 40 GB (Servers 2 & 3) — MIG-capable: 7 compute slices.
    A100_40GB,
    /// NVIDIA A30 (Server 2) — MIG-capable: 4 compute slices.
    A30,
    /// AMD-Xilinx Alveo U50 (Server 2).
    AlveoU50,
    /// AMD-Xilinx Alveo U250 (Servers 2 & 3).
    AlveoU250,
    /// AMD-Xilinx Alveo U55C (Server 4).
    AlveoU55C,
}

impl GpuModel {
    pub fn parse(s: &str) -> Option<GpuModel> {
        Some(match s {
            "T4" | "TeslaT4" | "tesla-t4" => GpuModel::TeslaT4,
            "RTX5000" | "rtx-5000" => GpuModel::Rtx5000,
            "A100" | "A100-40GB" | "a100" => GpuModel::A100_40GB,
            "A30" | "a30" => GpuModel::A30,
            "U50" | "u50" => GpuModel::AlveoU50,
            "U250" | "u250" => GpuModel::AlveoU250,
            "U55C" | "u55c" => GpuModel::AlveoU55C,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            GpuModel::TeslaT4 => "Tesla-T4",
            GpuModel::Rtx5000 => "RTX-5000",
            GpuModel::A100_40GB => "A100-40GB",
            GpuModel::A30 => "A30",
            GpuModel::AlveoU50 => "Alveo-U50",
            GpuModel::AlveoU250 => "Alveo-U250",
            GpuModel::AlveoU55C => "Alveo-U55C",
        }
    }

    pub fn is_fpga(&self) -> bool {
        matches!(self, GpuModel::AlveoU50 | GpuModel::AlveoU250 | GpuModel::AlveoU55C)
    }

    /// Total device memory in bytes.
    pub fn memory_bytes(&self) -> u64 {
        let gb = match self {
            GpuModel::TeslaT4 => 16,
            GpuModel::Rtx5000 => 16,
            GpuModel::A100_40GB => 40,
            GpuModel::A30 => 24,
            GpuModel::AlveoU50 => 8,
            GpuModel::AlveoU250 => 64,
            GpuModel::AlveoU55C => 16,
        };
        gb * (1 << 30)
    }

    /// Peak dense FP16/BF16 tensor throughput (TFLOPS) — the job cost model's
    /// numerator for ML payloads.
    pub fn peak_tensor_tflops(&self) -> f64 {
        match self {
            GpuModel::TeslaT4 => 65.0,
            GpuModel::Rtx5000 => 89.2,
            GpuModel::A100_40GB => 312.0,
            GpuModel::A30 => 165.0,
            // FPGA boards: not used for the ML payloads in this repro;
            // nominal INT8 inference envelope for completeness.
            GpuModel::AlveoU50 => 8.0,
            GpuModel::AlveoU250 => 11.0,
            GpuModel::AlveoU55C => 9.0,
        }
    }

    /// MIG compute-slice capacity (0 = not MIG capable).
    pub fn mig_compute_slices(&self) -> u8 {
        match self {
            GpuModel::A100_40GB => 7,
            GpuModel::A30 => 4,
            _ => 0,
        }
    }

    /// Board power envelope in watts (telemetry simulation).
    pub fn tdp_watts(&self) -> f64 {
        match self {
            GpuModel::TeslaT4 => 70.0,
            GpuModel::Rtx5000 => 230.0,
            GpuModel::A100_40GB => 400.0,
            GpuModel::A30 => 165.0,
            GpuModel::AlveoU50 => 75.0,
            GpuModel::AlveoU250 => 225.0,
            GpuModel::AlveoU55C => 150.0,
        }
    }
}

impl crate::util::codec::Enc for GpuModel {
    fn enc(&self, b: &mut Vec<u8>) {
        let tag: u8 = match self {
            GpuModel::TeslaT4 => 0,
            GpuModel::Rtx5000 => 1,
            GpuModel::A100_40GB => 2,
            GpuModel::A30 => 3,
            GpuModel::AlveoU50 => 4,
            GpuModel::AlveoU250 => 5,
            GpuModel::AlveoU55C => 6,
        };
        b.push(tag);
    }
}

impl crate::util::codec::Dec for GpuModel {
    fn dec(
        r: &mut crate::util::codec::Reader<'_>,
    ) -> Result<Self, crate::util::codec::CodecError> {
        Ok(match crate::util::codec::Dec::dec(r).map(|t: u8| t)? {
            0 => GpuModel::TeslaT4,
            1 => GpuModel::Rtx5000,
            2 => GpuModel::A100_40GB,
            3 => GpuModel::A30,
            4 => GpuModel::AlveoU50,
            5 => GpuModel::AlveoU250,
            6 => GpuModel::AlveoU55C,
            t => return Err(crate::util::codec::CodecError(format!("bad gpu model tag {t}"))),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_for_inventory_names() {
        for s in ["T4", "RTX5000", "A100", "A30", "U50", "U250", "U55C"] {
            assert!(GpuModel::parse(s).is_some(), "{s}");
        }
        assert!(GpuModel::parse("H100").is_none());
    }

    #[test]
    fn only_ampere_is_mig_capable() {
        assert_eq!(GpuModel::A100_40GB.mig_compute_slices(), 7);
        assert_eq!(GpuModel::A30.mig_compute_slices(), 4);
        assert_eq!(GpuModel::TeslaT4.mig_compute_slices(), 0);
        assert_eq!(GpuModel::Rtx5000.mig_compute_slices(), 0);
    }

    #[test]
    fn fpga_flags() {
        assert!(GpuModel::AlveoU250.is_fpga());
        assert!(!GpuModel::A100_40GB.is_fpga());
    }

    #[test]
    fn a100_memory_is_40gb() {
        assert_eq!(GpuModel::A100_40GB.memory_bytes(), 40 << 30);
    }
}
