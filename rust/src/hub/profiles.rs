//! Session environment profiles (paper §2: preconfigured Conda
//! environments / Apptainer images for TensorFlow, Torch, Keras, QML; or
//! fully custom OCI images) and the hardware presets users pick in the
//! JupyterHub spawn dialog.

use crate::cluster::resources::{ResourceVec, CPU, GPU, MEMORY};
use crate::gpu::MigProfile;

/// Software environment source.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnvKind {
    /// Managed conda env distributed on the platform filesystem.
    Conda { env_name: String },
    /// Apptainer image from the managed area.
    Apptainer { image: String },
    /// User-supplied OCI image (max flexibility).
    Oci { image: String },
}

/// Hardware flavor for the session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HwFlavor {
    CpuOnly,
    MigSlice(MigProfile),
    WholeGpu,
}

/// A spawnable profile.
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: String,
    pub env: EnvKind,
    pub hw: HwFlavor,
    pub cpu_millis: i64,
    pub mem_bytes: i64,
}

impl Profile {
    /// Resource requests the spawned pod will carry.
    ///
    /// The production fleet keeps its A100s in the max-sharing 7×1g.5gb
    /// layout (configs/ai_infn.json), so larger MIG asks are expressed as
    /// *compute-slice equivalents*: a "3g" profile requests three 1g.5gb
    /// instances (see DESIGN.md substitution table) rather than one
    /// 3g.20gb instance that the fleet does not advertise.
    pub fn requests(&self) -> ResourceVec {
        let mut r = ResourceVec::new().with(CPU, self.cpu_millis).with(MEMORY, self.mem_bytes);
        match self.hw {
            HwFlavor::CpuOnly => {}
            HwFlavor::MigSlice(p) => {
                r.set(&MigProfile::new(1, 5).resource_name(), p.compute_slices as i64)
            }
            HwFlavor::WholeGpu => r.set(GPU, 1),
        }
        r
    }
}

/// The catalogue profile name matching a synthetic-trace GPU demand
/// (shared by the CLI `up` replay and the trace-driven examples).
pub fn profile_for_demand(demand: crate::sim::trace::GpuDemand) -> &'static str {
    use crate::sim::trace::GpuDemand;
    match demand {
        GpuDemand::None => "cpu-small",
        GpuDemand::MigSlice(1) => "tensorflow-mig-1g",
        GpuDemand::MigSlice(_) => "torch-mig-3g",
        GpuDemand::WholeGpu => "full-a100",
    }
}

/// The platform's default profile catalogue (mirrors the hub spawn page).
pub fn default_catalogue() -> Vec<Profile> {
    vec![
        Profile {
            name: "cpu-small".into(),
            env: EnvKind::Conda { env_name: "base".into() },
            hw: HwFlavor::CpuOnly,
            cpu_millis: 2000,
            mem_bytes: 8 << 30,
        },
        Profile {
            name: "tensorflow-mig-1g".into(),
            env: EnvKind::Conda { env_name: "tensorflow-2.16".into() },
            hw: HwFlavor::MigSlice(MigProfile::new(1, 5)),
            cpu_millis: 4000,
            mem_bytes: 16 << 30,
        },
        Profile {
            name: "torch-mig-3g".into(),
            env: EnvKind::Conda { env_name: "torch-2.4".into() },
            hw: HwFlavor::MigSlice(MigProfile::new(3, 20)),
            cpu_millis: 8000,
            mem_bytes: 32 << 30,
        },
        Profile {
            name: "qml-apptainer".into(),
            env: EnvKind::Apptainer { image: "qml-pennylane.sif".into() },
            hw: HwFlavor::MigSlice(MigProfile::new(1, 5)),
            cpu_millis: 4000,
            mem_bytes: 16 << 30,
        },
        Profile {
            name: "full-a100".into(),
            env: EnvKind::Oci { image: "user/custom:latest".into() },
            hw: HwFlavor::WholeGpu,
            cpu_millis: 16000,
            mem_bytes: 64 << 30,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalogue_has_conda_apptainer_oci() {
        let c = default_catalogue();
        assert!(c.iter().any(|p| matches!(p.env, EnvKind::Conda { .. })));
        assert!(c.iter().any(|p| matches!(p.env, EnvKind::Apptainer { .. })));
        assert!(c.iter().any(|p| matches!(p.env, EnvKind::Oci { .. })));
    }

    #[test]
    fn requests_carry_mig_resource() {
        let c = default_catalogue();
        let mig = c.iter().find(|p| p.name == "tensorflow-mig-1g").unwrap();
        assert_eq!(mig.requests().get("nvidia.com/mig-1g.5gb"), 1);
        assert_eq!(mig.requests().get(CPU), 4000);
        // a "3g" profile asks for 3 compute-slice equivalents on the 7×1g fleet
        let three = c.iter().find(|p| p.name == "torch-mig-3g").unwrap();
        assert_eq!(three.requests().get("nvidia.com/mig-1g.5gb"), 3);
        let full = c.iter().find(|p| p.name == "full-a100").unwrap();
        assert_eq!(full.requests().get(GPU), 1);
        let cpu = c.iter().find(|p| p.name == "cpu-small").unwrap();
        assert_eq!(cpu.requests().get(GPU), 0);
    }
}
