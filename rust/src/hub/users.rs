//! User and project registry (paper §2: "78 INFN Cloud users registered to
//! the AI_INFN platform and 20 multi-user research projects were allocated").

use std::collections::BTreeMap;

/// A registered platform user.
#[derive(Debug, Clone)]
pub struct User {
    pub name: String,
    pub projects: Vec<String>,
    pub home_volume: String,
    pub registered_at: f64,
}

/// A multi-user research project with a shared volume and a GPU-hours grant.
#[derive(Debug, Clone)]
pub struct Project {
    pub name: String,
    pub shared_volume: String,
    pub gpu_hours_grant: f64,
    pub members: Vec<String>,
}

/// The registry.
#[derive(Debug, Default)]
pub struct Registry {
    users: BTreeMap<String, User>,
    projects: BTreeMap<String, Project>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_user(&mut self, name: &str, at: f64) -> anyhow::Result<&User> {
        anyhow::ensure!(!self.users.contains_key(name), "user {name} already registered");
        self.users.insert(
            name.to_string(),
            User {
                name: name.to_string(),
                projects: Vec::new(),
                home_volume: format!("home-{name}"),
                registered_at: at,
            },
        );
        Ok(&self.users[name])
    }

    pub fn create_project(&mut self, name: &str, gpu_hours_grant: f64) -> anyhow::Result<&Project> {
        anyhow::ensure!(!self.projects.contains_key(name), "project {name} exists");
        self.projects.insert(
            name.to_string(),
            Project {
                name: name.to_string(),
                shared_volume: format!("proj-{name}"),
                gpu_hours_grant,
                members: Vec::new(),
            },
        );
        Ok(&self.projects[name])
    }

    pub fn add_member(&mut self, project: &str, user: &str) -> anyhow::Result<()> {
        anyhow::ensure!(self.users.contains_key(user), "no user {user}");
        let p = self
            .projects
            .get_mut(project)
            .ok_or_else(|| anyhow::anyhow!("no project {project}"))?;
        if !p.members.iter().any(|m| m == user) {
            p.members.push(user.to_string());
        }
        let u = self.users.get_mut(user).unwrap();
        if !u.projects.iter().any(|x| x == project) {
            u.projects.push(project.to_string());
        }
        Ok(())
    }

    pub fn user(&self, name: &str) -> Option<&User> {
        self.users.get(name)
    }

    pub fn project(&self, name: &str) -> Option<&Project> {
        self.projects.get(name)
    }

    pub fn user_count(&self) -> usize {
        self.users.len()
    }

    pub fn project_count(&self) -> usize {
        self.projects.len()
    }

    pub fn users(&self) -> impl Iterator<Item = &User> {
        self.users.values()
    }

    pub fn projects(&self) -> impl Iterator<Item = &Project> {
        self.projects.values()
    }

    /// Seed the paper's population: 78 users across 20 projects (Zipf-ish
    /// membership so a few projects are large, like real research groups).
    pub fn seed_paper_population(&mut self) {
        for p in 0..20 {
            self.create_project(&format!("project{p:02}"), 5000.0).unwrap();
        }
        for u in 0..78 {
            let name = format!("user{u:03}");
            self.register_user(&name, 0.0).unwrap();
            self.add_member(&format!("project{:02}", u % 20), &name).unwrap();
            // heavier users join a second project
            if u % 3 == 0 {
                self.add_member(&format!("project{:02}", (u / 3) % 20), &name).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_membership() {
        let mut r = Registry::new();
        r.register_user("alice", 0.0).unwrap();
        r.create_project("lhcb", 1000.0).unwrap();
        r.add_member("lhcb", "alice").unwrap();
        assert_eq!(r.user("alice").unwrap().projects, vec!["lhcb"]);
        assert_eq!(r.project("lhcb").unwrap().members, vec!["alice"]);
        // idempotent add
        r.add_member("lhcb", "alice").unwrap();
        assert_eq!(r.project("lhcb").unwrap().members.len(), 1);
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        let mut r = Registry::new();
        r.register_user("alice", 0.0).unwrap();
        assert!(r.register_user("alice", 1.0).is_err());
        assert!(r.add_member("nope", "alice").is_err());
        assert!(r.add_member("lhcb", "ghost").is_err());
    }

    #[test]
    fn paper_population_counts() {
        let mut r = Registry::new();
        r.seed_paper_population();
        assert_eq!(r.user_count(), 78);
        assert_eq!(r.project_count(), 20);
        // every user belongs to >= 1 project
        assert!(r.users().all(|u| !u.projects.is_empty()));
        // every project has >= 1 member
        assert!(r.projects().all(|p| !p.members.is_empty()));
    }
}
