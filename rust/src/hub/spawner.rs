//! The JupyterHub-like spawner: turns a (user, profile) request into a
//! provisioned session — home/project volumes on the platform filesystem,
//! an IAM token, the automated rclone bucket mount, a Kueue workload in the
//! interactive queue, and finally the session pod.
//!
//! This is the paper's §2 spawn-time sequence: "At spawn time, JupyterHub is
//! configured to create the user's home directories and project-dedicated
//! shared volumes … the mount operation is automated at spawn time."

use crate::cluster::pod::{Payload, PodSpec};
use crate::cluster::store::ClusterStore;
use crate::hub::auth::AuthService;
use crate::hub::profiles::Profile;
use crate::hub::users::Registry;
use crate::queue::kueue::{Kueue, PriorityClass, WorkloadState};
use crate::sim::clock::Time;
use crate::storage::nfs::NfsServer;
use crate::storage::object::ObjectStore;
use crate::storage::rclone::RcloneMount;
use crate::util::codec::{CodecError, Dec, Enc, Reader};

/// Default per-user home quota (50 GiB) and project share quota (500 GiB).
pub const HOME_QUOTA: u64 = 50 << 30;
pub const PROJECT_QUOTA: u64 = 500 << 30;

/// A live session handle.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: String,
    pub user: String,
    pub profile: String,
    pub pod_name: String,
    pub workload_name: String,
    pub token: String,
    pub mount: Option<RcloneMount>,
    pub started_at: Time,
    pub last_activity: Time,
}

/// Everything the spawner touches (borrowed from the platform facade).
pub struct SpawnCtx<'a> {
    pub registry: &'a mut Registry,
    pub auth: &'a mut AuthService,
    pub nfs: &'a mut NfsServer,
    pub objects: &'a mut ObjectStore,
    pub kueue: &'a mut Kueue,
    pub cluster: &'a mut ClusterStore,
}

/// Spawn failure modes.
#[derive(Debug, thiserror::Error)]
pub enum SpawnError {
    #[error("unknown user {0}")]
    UnknownUser(String),
    #[error("session quota: user {0} already has an active session")]
    AlreadyActive(String),
    #[error("admission pending: interactive queue is saturated")]
    AdmissionPending,
    #[error(transparent)]
    Other(#[from] anyhow::Error),
}

/// The spawner service.
#[derive(Debug)]
pub struct Spawner {
    pub hub_queue: String,
    pub token_ttl: Time,
    pub idle_timeout: Time,
    next_id: u64,
    sessions: Vec<Session>,
}

impl Spawner {
    pub fn new(hub_queue: &str) -> Self {
        Spawner {
            hub_queue: hub_queue.to_string(),
            token_ttl: 12.0 * 3600.0,
            idle_timeout: 2.0 * 3600.0,
            next_id: 0,
            sessions: Vec::new(),
        }
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    pub fn active_session_for(&self, user: &str) -> Option<&Session> {
        self.sessions.iter().find(|s| s.user == user)
    }

    /// Full spawn sequence. On success the pod is Pending in the cluster
    /// store (the platform's scheduler pass will bind it) and the Kueue
    /// workload is Admitted.
    pub fn spawn(
        &mut self,
        ctx: &mut SpawnCtx,
        user: &str,
        profile: &Profile,
        at: Time,
    ) -> Result<Session, SpawnError> {
        let u = ctx
            .registry
            .user(user)
            .ok_or_else(|| SpawnError::UnknownUser(user.to_string()))?
            .clone();
        if self.active_session_for(user).is_some() {
            return Err(SpawnError::AlreadyActive(user.to_string()));
        }

        // 1. volumes: home + per-project shares (idempotent)
        if ctx.nfs.volume(&u.home_volume).is_none() {
            ctx.nfs
                .create_volume(&u.home_volume, HOME_QUOTA)
                .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        }
        for p in &u.projects {
            let vol = &ctx
                .registry
                .project(p)
                .ok_or_else(|| anyhow::anyhow!("dangling project {p}"))?
                .shared_volume
                .clone();
            if ctx.nfs.volume(vol).is_none() {
                ctx.nfs
                    .create_volume(vol, PROJECT_QUOTA)
                    .map_err(|e| anyhow::anyhow!(e.to_string()))?;
            }
        }

        // 2. token (hub login credential, reused by the rclone mount)
        let token = ctx.auth.issue(user, self.token_ttl, at);

        // 3. bucket + automated mount
        let bucket = format!("{user}-bucket");
        if ctx.objects.create_bucket(&bucket, user).is_err() {
            // already exists — fine
        }
        let mount = RcloneMount::mount(ctx.auth, &token, &bucket, &format!("/home/{user}/bucket")).ok();

        // 4. Kueue admission in the interactive queue
        self.next_id += 1;
        let id = format!("session-{user}-{:04}", self.next_id);
        let requests = profile.requests();
        let wl_name = format!("wl-{id}");
        ctx.kueue
            .submit_for_user(
                &wl_name,
                &self.hub_queue,
                user,
                PriorityClass::Interactive,
                requests.clone(),
                at,
            )
            .map_err(SpawnError::Other)?;
        let result = ctx.kueue.admit_pass(at);
        let admitted = ctx
            .kueue
            .workload(&wl_name)
            .map(|w| w.state == WorkloadState::Admitted)
            .unwrap_or(false);
        let _ = result;
        if !admitted {
            // leave it queued; caller may retry/monitor
            return Err(SpawnError::AdmissionPending);
        }

        // 5. the session pod
        let pod_name = format!("jupyter-{id}");
        let spec = PodSpec::new(
            pod_name.clone(),
            requests,
            Payload::Session { idle_after: self.idle_timeout },
        )
        .with_label("app", "jupyterlab")
        .with_label("aiinfn/session", &id)
        .with_priority(PriorityClass::Interactive.value())
        .with_owner(user, u.projects.first().map(|s| s.as_str()).unwrap_or("none"))
        .in_namespace("hub");
        ctx.cluster.create_pod(spec, at);

        let session = Session {
            id: id.clone(),
            user: user.to_string(),
            profile: profile.name.clone(),
            pod_name,
            workload_name: wl_name,
            token,
            mount,
            started_at: at,
            last_activity: at,
        };
        self.sessions.push(session.clone());
        Ok(session)
    }

    /// Record user activity (resets the idle culler timer).
    pub fn touch(&mut self, session_id: &str, at: Time) {
        if let Some(s) = self.sessions.iter_mut().find(|s| s.id == session_id) {
            s.last_activity = at;
        }
    }

    /// Stop a session: finish the workload, terminate the pod.
    pub fn stop(
        &mut self,
        ctx: &mut SpawnCtx,
        session_id: &str,
        at: Time,
        reason: &str,
    ) -> anyhow::Result<()> {
        let idx = self
            .sessions
            .iter()
            .position(|s| s.id == session_id)
            .ok_or_else(|| anyhow::anyhow!("no session {session_id}"))?;
        let s = self.sessions.remove(idx);
        ctx.kueue.finish(&s.workload_name, at).ok();
        if let Some(pod) = ctx.cluster.pod(&s.pod_name) {
            match pod.status.phase {
                crate::cluster::pod::PodPhase::Running
                | crate::cluster::pod::PodPhase::Scheduled => {
                    ctx.cluster
                        .finish_pod(&s.pod_name, crate::cluster::pod::PodPhase::Succeeded, at, reason)?;
                }
                crate::cluster::pod::PodPhase::Pending => {
                    // never scheduled: mark failed-terminal via evict(no requeue)
                    // Pending pods hold no capacity; just record.
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// The idle culler (paper: sessions are reclaimed to keep accelerators
    /// available). Returns culled session ids.
    pub fn cull_idle(&mut self, ctx: &mut SpawnCtx, at: Time) -> Vec<String> {
        let victims: Vec<String> = self
            .sessions
            .iter()
            .filter(|s| at - s.last_activity >= self.idle_timeout)
            .map(|s| s.id.clone())
            .collect();
        for v in &victims {
            self.stop(ctx, v, at, "idle-culled").ok();
        }
        victims
    }
}

// --- durability codecs ------------------------------------------------
//
// Sessions and the id counter are facade-local control state: a restored
// coordinator must keep culling/stopping live sessions and must not mint
// colliding `session-*` ids.

impl Enc for Session {
    fn enc(&self, b: &mut Vec<u8>) {
        self.id.enc(b);
        self.user.enc(b);
        self.profile.enc(b);
        self.pod_name.enc(b);
        self.workload_name.enc(b);
        self.token.enc(b);
        self.mount.enc(b);
        self.started_at.enc(b);
        self.last_activity.enc(b);
    }
}

impl Dec for Session {
    fn dec(r: &mut Reader) -> Result<Self, CodecError> {
        Ok(Session {
            id: String::dec(r)?,
            user: String::dec(r)?,
            profile: String::dec(r)?,
            pod_name: String::dec(r)?,
            workload_name: String::dec(r)?,
            token: String::dec(r)?,
            mount: Option::dec(r)?,
            started_at: Time::dec(r)?,
            last_activity: Time::dec(r)?,
        })
    }
}

impl Enc for Spawner {
    fn enc(&self, b: &mut Vec<u8>) {
        self.hub_queue.enc(b);
        self.token_ttl.enc(b);
        self.idle_timeout.enc(b);
        self.next_id.enc(b);
        self.sessions.enc(b);
    }
}

impl Dec for Spawner {
    fn dec(r: &mut Reader) -> Result<Self, CodecError> {
        Ok(Spawner {
            hub_queue: String::dec(r)?,
            token_ttl: Time::dec(r)?,
            idle_timeout: Time::dec(r)?,
            next_id: u64::dec(r)?,
            sessions: Vec::dec(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Node;
    use crate::cluster::resources::{ResourceVec, GPU};
    use crate::gpu::{GpuDevice, GpuModel};
    use crate::hub::profiles::default_catalogue;
    use crate::queue::kueue::{ClusterQueue, LocalQueue};

    struct World {
        registry: Registry,
        auth: AuthService,
        nfs: NfsServer,
        objects: ObjectStore,
        kueue: Kueue,
        cluster: ClusterStore,
        spawner: Spawner,
    }

    fn world() -> World {
        let mut registry = Registry::new();
        registry.register_user("alice", 0.0).unwrap();
        registry.create_project("lhcb", 100.0).unwrap();
        registry.add_member("lhcb", "alice").unwrap();
        let mut kueue = Kueue::new();
        kueue.add_cluster_queue(ClusterQueue {
            name: "interactive-cq".into(),
            cohort: None,
            nominal: ResourceVec::cpu_millis(64_000)
                .with(crate::cluster::resources::MEMORY, 512 << 30)
                .with(GPU, 2)
                .with("nvidia.com/mig-1g.5gb", 7),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: true,
        });
        kueue.add_local_queue(LocalQueue { name: "hub".into(), cluster_queue: "interactive-cq".into() });
        let mut cluster = ClusterStore::new();
        cluster.add_node(
            Node::physical("n1", 64, 512 << 30, 10 << 40, vec![GpuDevice::whole("g0", GpuModel::TeslaT4)]),
            0.0,
        );
        World {
            registry,
            auth: AuthService::new("seed"),
            nfs: NfsServer::new(),
            objects: ObjectStore::new(),
            kueue,
            cluster,
            spawner: Spawner::new("hub"),
        }
    }

    /// Split-borrow helper: yields (SpawnCtx, &mut Spawner).
    macro_rules! split {
        ($w:expr) => {{
            let World { registry, auth, nfs, objects, kueue, cluster, spawner } = $w;
            (SpawnCtx { registry, auth, nfs, objects, kueue, cluster }, spawner)
        }};
    }

    #[test]
    fn spawn_provisions_everything() {
        let mut w = world();
        let profile = default_catalogue().into_iter().find(|p| p.name == "cpu-small").unwrap();
        let s = {
            let (mut c, spawner) = split!(&mut w);
            spawner.spawn(&mut c, "alice", &profile, 10.0).unwrap()
        };
        // volumes created
        assert!(w.nfs.volume("home-alice").is_some());
        assert!(w.nfs.volume("proj-lhcb").is_some());
        // token valid
        use crate::hub::auth::TokenValidator;
        assert_eq!(w.auth.validate(&s.token), Some("alice".into()));
        // mount established
        assert!(s.mount.is_some());
        // kueue admitted + pod pending
        assert_eq!(
            w.kueue.workload(&s.workload_name).unwrap().state,
            WorkloadState::Admitted
        );
        assert!(w.cluster.pod(&s.pod_name).is_some());
    }

    #[test]
    fn double_spawn_rejected() {
        let mut w = world();
        let profile = default_catalogue().into_iter().find(|p| p.name == "cpu-small").unwrap();
        {
            let (mut c, spawner) = split!(&mut w);
            spawner.spawn(&mut c, "alice", &profile, 0.0).unwrap();
        }
        let (mut c, spawner) = split!(&mut w);
        let e = spawner.spawn(&mut c, "alice", &profile, 1.0).unwrap_err();
        assert!(matches!(e, SpawnError::AlreadyActive(_)));
    }

    #[test]
    fn unknown_user_rejected() {
        let mut w = world();
        let profile = default_catalogue().remove(0);
        let (mut c, spawner) = split!(&mut w);
        assert!(matches!(
            spawner.spawn(&mut c, "mallory", &profile, 0.0),
            Err(SpawnError::UnknownUser(_))
        ));
    }

    #[test]
    fn gpu_session_blocks_when_quota_full_then_admits() {
        let mut w = world();
        // whole-GPU profile; quota has 2 whole GPUs
        let profile = default_catalogue().into_iter().find(|p| p.name == "full-a100").unwrap();
        w.registry.register_user("bob", 0.0).unwrap();
        w.registry.register_user("carol", 0.0).unwrap();
        {
            let (mut c, spawner) = split!(&mut w);
            spawner.spawn(&mut c, "alice", &profile, 0.0).unwrap();
            spawner.spawn(&mut c, "bob", &profile, 0.0).unwrap();
            let e = spawner.spawn(&mut c, "carol", &profile, 0.0).unwrap_err();
            assert!(matches!(e, SpawnError::AdmissionPending));
        }
        // alice stops → carol can retry
        let sid = w.spawner.active_session_for("alice").unwrap().id.clone();
        {
            let (mut c, spawner) = split!(&mut w);
            spawner.stop(&mut c, &sid, 100.0, "logout").unwrap();
        }
        // carol's earlier workload is still queued; the admit pass releases it
        let r = w.kueue.admit_pass(101.0);
        assert_eq!(r.admitted.len(), 1);
    }

    #[test]
    fn culler_reclaims_idle_sessions() {
        let mut w = world();
        w.spawner.idle_timeout = 100.0;
        let profile = default_catalogue().remove(0);
        let sid = {
            let (mut c, spawner) = split!(&mut w);
            spawner.spawn(&mut c, "alice", &profile, 0.0).unwrap().id
        };
        // activity at t=50 postpones culling
        w.spawner.touch(&sid, 50.0);
        {
            let (mut c, spawner) = split!(&mut w);
            assert!(spawner.cull_idle(&mut c, 120.0).is_empty());
            let culled = spawner.cull_idle(&mut c, 151.0);
            assert_eq!(culled, vec![sid.clone()]);
        }
        assert!(w.spawner.active_session_for("alice").is_none());
        // quota released
        let (used, _) = w.kueue.quota_utilization();
        assert!(used.is_empty());
    }

    #[test]
    fn snapshot_roundtrip_keeps_sessions_and_id_counter() {
        let mut w = world();
        let profile = default_catalogue().into_iter().find(|p| p.name == "cpu-small").unwrap();
        let s = {
            let (mut c, spawner) = split!(&mut w);
            spawner.spawn(&mut c, "alice", &profile, 10.0).unwrap()
        };
        let bytes = w.spawner.to_bytes();
        let back = Spawner::from_bytes(&bytes).unwrap();
        assert_eq!(back.to_bytes(), bytes);
        let restored = back.active_session_for("alice").unwrap();
        assert_eq!(restored.id, s.id);
        assert_eq!(restored.pod_name, s.pod_name);
        assert!(restored.mount.is_some());
        // the id counter survived: a double-spawn is still rejected, and the
        // counter continues past the restored value
        w.spawner = back;
        let (mut c, spawner) = split!(&mut w);
        assert!(matches!(
            spawner.spawn(&mut c, "alice", &profile, 11.0),
            Err(SpawnError::AlreadyActive(_))
        ));
    }
}
