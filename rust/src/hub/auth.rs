//! Hub authentication: HMAC-signed bearer tokens with expiry.
//!
//! Stands in for the INFN Cloud IAM integration: JupyterHub issues a token
//! at login; the same token authenticates the object-store mount (the
//! patched-rclone flow, `storage::rclone`) and the InterLink offload calls.
//! Tokens are `user:expiry:hex(hmac-sha256(secret, user|expiry))` — stateless
//! validation, like a minimal JWT.

use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

/// Anything that can validate a bearer token to a user name.
pub trait TokenValidator {
    /// Returns the authenticated user, or None if invalid/expired.
    fn validate(&self, token: &str) -> Option<String>;
}

/// The token service. Holds the signing secret and a notion of "now"
/// (injected so simulations control expiry).
#[derive(Debug)]
pub struct AuthService {
    secret: Vec<u8>,
    now: f64,
}

impl AuthService {
    pub fn new(secret: &str) -> Self {
        AuthService { secret: secret.as_bytes().to_vec(), now: 0.0 }
    }

    /// Advance the validator's clock (sim time).
    pub fn set_now(&mut self, now: f64) {
        self.now = now;
    }

    fn sign(&self, user: &str, expiry: f64) -> String {
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.secret).expect("hmac key");
        mac.update(user.as_bytes());
        mac.update(b"|");
        mac.update(format!("{expiry:.3}").as_bytes());
        let sig = mac.finalize().into_bytes();
        sig.iter().take(16).map(|b| format!("{b:02x}")).collect()
    }

    /// Issue a token for `user` valid for `ttl` seconds from `now`.
    pub fn issue(&mut self, user: &str, ttl: f64, now: f64) -> String {
        self.now = self.now.max(now);
        let expiry = now + ttl;
        format!("{user}:{expiry:.3}:{}", self.sign(user, expiry))
    }
}

impl TokenValidator for AuthService {
    fn validate(&self, token: &str) -> Option<String> {
        let mut parts = token.splitn(3, ':');
        let user = parts.next()?;
        let expiry: f64 = parts.next()?.parse().ok()?;
        let sig = parts.next()?;
        if expiry < self.now {
            return None;
        }
        // constant-time-ish compare via hmac recompute
        if self.sign(user, expiry) == sig {
            Some(user.to_string())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_validate_roundtrip() {
        let mut a = AuthService::new("s3cret");
        let t = a.issue("alice", 3600.0, 100.0);
        assert_eq!(a.validate(&t), Some("alice".to_string()));
    }

    #[test]
    fn expiry_enforced() {
        let mut a = AuthService::new("s3cret");
        let t = a.issue("alice", 10.0, 0.0);
        a.set_now(10.5);
        assert_eq!(a.validate(&t), None);
    }

    #[test]
    fn tampered_token_rejected() {
        let mut a = AuthService::new("s3cret");
        let t = a.issue("alice", 3600.0, 0.0);
        let forged = t.replace("alice", "admin");
        assert_eq!(a.validate(&forged), None);
        assert_eq!(a.validate("garbage"), None);
        assert_eq!(a.validate(""), None);
    }

    #[test]
    fn different_secrets_do_not_cross_validate() {
        let mut a = AuthService::new("secret-a");
        let b = AuthService::new("secret-b");
        let t = a.issue("alice", 3600.0, 0.0);
        assert_eq!(b.validate(&t), None);
    }
}
