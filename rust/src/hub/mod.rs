//! JupyterHub-like interactive layer (DESIGN.md S13): token auth, the
//! user/project registry, spawn profiles, the spawner, and the idle culler.

pub mod auth;
pub mod profiles;
pub mod spawner;
pub mod users;

pub use auth::{AuthService, TokenValidator};
pub use profiles::{default_catalogue, EnvKind, HwFlavor, Profile};
pub use spawner::{Session, SpawnCtx, SpawnError, Spawner};
pub use users::{Project, Registry, User};
