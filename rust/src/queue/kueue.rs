//! Kueue-like job queueing: quota-based admission with priorities, cohort
//! borrowing, and interactive-first preemption.
//!
//! This models the controller the paper deploys (§3): *"The local batch
//! system is managed by Kueue ... designed to opportunistically run
//! non-interactive workloads ... Kueue is configured to prioritize
//! JupyterLab sessions. If resource contention occurs, running batch jobs
//! are automatically evicted to free up hardware for interactive
//! development."*
//!
//! Objects follow upstream Kueue: a [`ClusterQueue`] holds nominal quota per
//! resource; [`LocalQueue`]s map namespaces onto cluster queues; a
//! [`Workload`] is the queued unit. Queues in the same *cohort* may borrow
//! each other's unused quota (how the batch queue opportunistically uses the
//! interactive queue's idle GPUs at night).

use std::collections::HashMap;

use crate::cluster::resources::ResourceVec;
use crate::cluster::wal::{KueueOp, WalHandle, WalRecord};
use crate::sim::clock::Time;
use crate::util::codec::{CodecError, Dec, Enc, Reader};
use crate::util::ring::{Compacted, RingLog};

/// Priority classes used on the platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PriorityClass {
    /// Opportunistic batch — evictable.
    Batch = 0,
    /// Production batch (paper: Snakemake controllers etc.).
    BatchHigh = 50,
    /// Interactive JupyterLab sessions — never evicted for batch.
    Interactive = 100,
}

impl PriorityClass {
    pub fn value(&self) -> i32 {
        *self as i32
    }
}

/// Admission state of a workload.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadState {
    /// Waiting for quota.
    Queued,
    /// Quota reserved; pods may be created.
    Admitted,
    /// Evicted due to contention; back in queue after backoff.
    EvictedPendingRequeue { until: Time },
    Finished,
}

/// The queued unit: one job's aggregate resource ask.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub queue: String, // LocalQueue name
    pub priority: PriorityClass,
    pub requests: ResourceVec,
    pub state: WorkloadState,
    pub created_at: Time,
    pub admitted_at: Option<Time>,
    pub evictions: u32,
    /// Which ClusterQueue's quota the admission drew from (for borrowing
    /// accounting: may differ from the owning queue).
    pub charged_to: Option<String>,
    /// Owning user — the fair-share tiebreak key (empty when unattributed:
    /// such workloads share one zero-usage bucket and keep plain FIFO).
    pub user: String,
}

/// Nominal quota holder.
#[derive(Debug, Clone)]
pub struct ClusterQueue {
    pub name: String,
    pub cohort: Option<String>,
    pub nominal: ResourceVec,
    /// Quota currently consumed by admitted workloads charged here.
    pub used: ResourceVec,
    /// May workloads of this queue borrow unused quota in the cohort?
    pub can_borrow: bool,
    /// May idle quota of this queue be lent to the cohort?
    pub can_lend: bool,
}

impl ClusterQueue {
    pub fn free(&self) -> ResourceVec {
        self.nominal.checked_sub(&self.used).unwrap_or_default()
    }
}

/// Namespace-facing queue → ClusterQueue mapping.
#[derive(Debug, Clone)]
pub struct LocalQueue {
    pub name: String,
    pub cluster_queue: String,
}

/// Admission state of a gang (all-or-nothing group of workloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GangState {
    /// Reserving quota member by member; nothing is schedulable yet.
    Pending,
    /// Every member reserved — all flipped to `Admitted` atomically.
    Bound,
    /// Every member finished (stage completed or cancelled).
    Finished,
}

/// An all-or-nothing admission group: the members of a multi-pod workflow
/// stage admit together or not at all. Reservation is incremental (a gang
/// may hold quota for a subset of its members across passes) with a
/// deadlock breaker: a gang whose partial reservation stops growing for
/// `gang_reserve_timeout` releases everything and re-tries after an
/// exponential, rank-staggered backoff — so two half-admitted gangs cannot
/// starve each other indefinitely.
#[derive(Debug, Clone)]
pub struct Gang {
    pub name: String,
    /// Member workload names, in submit order (also the reserve order).
    pub members: Vec<String>,
    pub priority: PriorityClass,
    pub created_at: Time,
    pub state: GangState,
    /// Members currently holding reserved quota (still `Queued`).
    pub reserved: Vec<String>,
    /// Stall-release rounds so far (drives the exponential backoff).
    pub attempts: u32,
    /// No reserve attempts before this time.
    pub backoff_until: Time,
    /// Last time the reservation grew (stall detection clock).
    pub last_progress: Time,
}

/// One workload state change, appended to the controller's transition log.
/// The API server's watch stream consumes these as deltas instead of
/// re-scanning every workload per tick.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadTransition {
    pub at: Time,
    pub workload: String,
    pub state: WorkloadState,
}

/// The Kueue controller state.
#[derive(Debug)]
pub struct Kueue {
    cluster_queues: HashMap<String, ClusterQueue>,
    local_queues: HashMap<String, LocalQueue>,
    workloads: HashMap<String, Workload>,
    /// FIFO arrival order for fair scanning.
    order: Vec<String>,
    /// Bounded log of workload state changes (ring with absolute cursors).
    transitions: RingLog<WorkloadTransition>,
    /// Requeue backoff base (doubles per eviction).
    pub backoff_base: Time,
    /// Decayed per-user GPU usage snapshot (set by the platform before
    /// each admission pass); the fair-share tiebreak within priority bands.
    fair_share: HashMap<String, f64>,
    /// All-or-nothing admission groups, keyed by gang name.
    gangs: HashMap<String, Gang>,
    /// Gang arrival order (deterministic service order within a band).
    gang_order: Vec<String>,
    /// Member workload → owning gang (members skip individual admission).
    gang_of: HashMap<String, String>,
    /// Seconds a partial gang reservation may sit without growing before
    /// the deadlock breaker releases it (`workflow.gang_reserve_timeout`).
    pub gang_reserve_timeout: Time,
    /// Write-ahead log sink. When attached, every public mutator appends
    /// its op at method entry for crash replay (same contract as
    /// [`ClusterStore`](crate::cluster::store::ClusterStore)).
    wal: Option<WalHandle>,
    /// Epoch (leader term) of the writer driving this controller — like
    /// the wal handle, runtime wiring, not snapshot state.
    writer_epoch: u64,
    /// Mutations from writer epochs below this are fenced (split-brain
    /// guard, raised at promotion).
    fenced_below: u64,
    /// Stale-epoch mutations rejected at the guard.
    fenced_writes: u64,
}

impl Default for Kueue {
    fn default() -> Self {
        Kueue {
            cluster_queues: HashMap::new(),
            local_queues: HashMap::new(),
            workloads: HashMap::new(),
            order: Vec::new(),
            // the shared ring default; Platform::bootstrap wires the
            // `control_plane.compaction_window` knob over it
            transitions: RingLog::default(),
            backoff_base: 0.0,
            fair_share: HashMap::new(),
            gangs: HashMap::new(),
            gang_order: Vec::new(),
            gang_of: HashMap::new(),
            gang_reserve_timeout: 60.0,
            wal: None,
            writer_epoch: 0,
            fenced_below: 0,
            fenced_writes: 0,
        }
    }
}

/// Outcome of an admission pass.
#[derive(Debug, Default, PartialEq)]
pub struct AdmissionResult {
    pub admitted: Vec<String>,
    /// Workloads evicted to make room (victims), with the preemptor.
    pub preempted: Vec<(String, String)>,
}

impl Kueue {
    pub fn new() -> Self {
        Kueue { backoff_base: 30.0, ..Default::default() }
    }

    // ----------------------------------------------------------- fencing

    /// Set the epoch (leader term) of the writer driving this controller.
    pub fn set_writer_epoch(&mut self, epoch: u64) {
        self.writer_epoch = epoch;
    }

    pub fn writer_epoch(&self) -> u64 {
        self.writer_epoch
    }

    /// Raise the split-brain fence: mutations from writer epochs below
    /// `epoch` are dropped at method entry (and counted) from here on.
    pub fn set_fence(&mut self, epoch: u64) {
        self.fenced_below = epoch;
    }

    /// Stale-epoch mutations rejected since this controller was created.
    pub fn fenced_writes(&self) -> u64 {
        self.fenced_writes
    }

    /// The mutation-entry guard (same contract as the store's): true and
    /// counted when the writer is deposed — drop the write, skip the log.
    fn fenced(&mut self) -> bool {
        if self.writer_epoch < self.fenced_below {
            self.fenced_writes += 1;
            true
        } else {
            false
        }
    }

    // --------------------------------------------------------------- wal

    /// Attach the write-ahead log: every public mutation from here on is
    /// appended (at method entry) for crash replay.
    pub fn attach_wal(&mut self, wal: WalHandle) {
        self.wal = Some(wal);
    }

    /// Detach the log (replay and snapshot restore run unlogged).
    pub fn detach_wal(&mut self) {
        self.wal = None;
    }

    fn log_op(&mut self, op: impl FnOnce() -> KueueOp) {
        if let Some(wal) = &self.wal {
            wal.borrow_mut().append(&WalRecord::Kueue(op()));
        }
    }

    /// Re-execute one logged op during replay (results dropped — failed
    /// calls were logged too and fail identically). Must run with the wal
    /// detached, or replay would append duplicate records.
    pub fn apply_op(&mut self, op: KueueOp) {
        debug_assert!(self.wal.is_none(), "replaying with a wal attached double-logs");
        match op {
            KueueOp::AddClusterQueue { cq } => self.add_cluster_queue(cq),
            KueueOp::AddLocalQueue { lq } => self.add_local_queue(lq),
            KueueOp::SubmitForUser { name, queue, user, priority, requests, at } => {
                let _ = self.submit_for_user(name, &queue, &user, priority, requests, at);
            }
            KueueOp::SetFairShare { usage } => self.set_fair_share(usage),
            KueueOp::AdjustNominal { queue, add, remove } => {
                let _ = self.adjust_nominal(&queue, &add, &remove);
            }
            KueueOp::AdmitPass { at } => {
                self.admit_pass(at);
            }
            KueueOp::Requeue { name, at } => {
                let _ = self.requeue(&name, at);
            }
            KueueOp::Finish { name, at } => {
                let _ = self.finish(&name, at);
            }
            KueueOp::SetTransitionCapacity { capacity } => self.set_transition_capacity(capacity),
            KueueOp::SubmitGang { name, queue, user, priority, members, at } => {
                let _ = self.submit_gang(&name, &queue, &user, priority, members, at);
            }
        }
    }

    pub fn add_cluster_queue(&mut self, cq: ClusterQueue) {
        if self.fenced() {
            return;
        }
        self.log_op(|| KueueOp::AddClusterQueue { cq: cq.clone() });
        self.cluster_queues.insert(cq.name.clone(), cq);
    }

    pub fn add_local_queue(&mut self, lq: LocalQueue) {
        if self.fenced() {
            return;
        }
        self.log_op(|| KueueOp::AddLocalQueue { lq: lq.clone() });
        assert!(
            self.cluster_queues.contains_key(&lq.cluster_queue),
            "local queue {} references unknown cluster queue {}",
            lq.name,
            lq.cluster_queue
        );
        self.local_queues.insert(lq.name.clone(), lq);
    }

    pub fn cluster_queue(&self, name: &str) -> Option<&ClusterQueue> {
        self.cluster_queues.get(name)
    }

    pub fn workload(&self, name: &str) -> Option<&Workload> {
        self.workloads.get(name)
    }

    pub fn workloads(&self) -> impl Iterator<Item = &Workload> {
        self.workloads.values()
    }

    /// Absolute cursor just past the newest transition; pass a previously
    /// returned cursor to [`transitions_since`](Self::transitions_since).
    pub fn transition_cursor(&self) -> usize {
        self.transitions.cursor()
    }

    /// Transitions recorded at or after `cursor` (watch-stream feed).
    /// Entries pruned before `cursor` are silently skipped — for
    /// renderers that tolerate partial history. Cursor-tracking pumps use
    /// [`transitions_since_checked`](Self::transitions_since_checked).
    pub fn transitions_since(
        &self,
        cursor: usize,
    ) -> impl Iterator<Item = &WorkloadTransition> {
        self.transitions.since_clamped(cursor)
    }

    /// Like [`transitions_since`](Self::transitions_since) but a cursor
    /// behind the retained window is a typed [`Compacted`] error — the
    /// consumer missed transitions and must re-list (Kubernetes 410 Gone).
    pub fn transitions_since_checked(
        &self,
        cursor: usize,
    ) -> Result<impl Iterator<Item = &WorkloadTransition>, Compacted> {
        self.transitions.since(cursor)
    }

    /// Reconfigure the transition log's retained window (the
    /// `control_plane.compaction_window` config knob).
    pub fn set_transition_capacity(&mut self, capacity: usize) {
        if self.fenced() {
            return;
        }
        self.log_op(|| KueueOp::SetTransitionCapacity { capacity });
        self.transitions.set_capacity(capacity);
    }

    /// Number of transitions currently retained (≤ the configured window).
    pub fn transition_log_len(&self) -> usize {
        self.transitions.len()
    }

    fn log_transition(&mut self, at: Time, workload: &str, state: WorkloadState) {
        self.transitions.push(WorkloadTransition {
            at,
            workload: workload.to_string(),
            state,
        });
    }

    /// Submit a workload to a LocalQueue (unattributed: no fair-share user).
    pub fn submit(
        &mut self,
        name: impl Into<String>,
        queue: &str,
        priority: PriorityClass,
        requests: ResourceVec,
        at: Time,
    ) -> anyhow::Result<String> {
        self.submit_for_user(name, queue, "", priority, requests, at)
    }

    /// Submit a workload attributed to `user` — the key the fair-share
    /// tiebreak orders by within a priority band.
    pub fn submit_for_user(
        &mut self,
        name: impl Into<String>,
        queue: &str,
        user: &str,
        priority: PriorityClass,
        requests: ResourceVec,
        at: Time,
    ) -> anyhow::Result<String> {
        let name = name.into();
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| KueueOp::SubmitForUser {
            name: name.clone(),
            queue: queue.to_string(),
            user: user.to_string(),
            priority,
            requests: requests.clone(),
            at,
        });
        anyhow::ensure!(self.local_queues.contains_key(queue), "unknown local queue {queue}");
        anyhow::ensure!(!self.workloads.contains_key(&name), "duplicate workload {name}");
        self.workloads.insert(
            name.clone(),
            Workload {
                name: name.clone(),
                queue: queue.to_string(),
                priority,
                requests,
                state: WorkloadState::Queued,
                created_at: at,
                admitted_at: None,
                evictions: 0,
                charged_to: None,
                user: user.to_string(),
            },
        );
        self.order.push(name.clone());
        self.log_transition(at, &name, WorkloadState::Queued);
        Ok(name)
    }

    /// Submit a gang: `members` are `(workload name, per-member request)`
    /// pairs admitted all-or-nothing. Members are ordinary workloads (the
    /// transition log, views, and `finish` see them individually) but they
    /// skip per-workload admission: quota is reserved member by member
    /// across admission passes and every member flips to `Admitted` in the
    /// same pass once the whole gang fits.
    pub fn submit_gang(
        &mut self,
        name: &str,
        queue: &str,
        user: &str,
        priority: PriorityClass,
        members: Vec<(String, ResourceVec)>,
        at: Time,
    ) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| KueueOp::SubmitGang {
            name: name.to_string(),
            queue: queue.to_string(),
            user: user.to_string(),
            priority,
            members: members.clone(),
            at,
        });
        anyhow::ensure!(self.local_queues.contains_key(queue), "unknown local queue {queue}");
        anyhow::ensure!(!members.is_empty(), "gang {name} has no members");
        anyhow::ensure!(!self.gangs.contains_key(name), "duplicate gang {name}");
        for (m, _) in &members {
            anyhow::ensure!(!self.workloads.contains_key(m), "duplicate workload {m}");
        }
        let mut member_names = Vec::with_capacity(members.len());
        for (m, req) in members {
            self.workloads.insert(
                m.clone(),
                Workload {
                    name: m.clone(),
                    queue: queue.to_string(),
                    priority,
                    requests: req,
                    state: WorkloadState::Queued,
                    created_at: at,
                    admitted_at: None,
                    evictions: 0,
                    charged_to: None,
                    user: user.to_string(),
                },
            );
            self.order.push(m.clone());
            self.log_transition(at, &m, WorkloadState::Queued);
            self.gang_of.insert(m.clone(), name.to_string());
            member_names.push(m);
        }
        self.gangs.insert(
            name.to_string(),
            Gang {
                name: name.to_string(),
                members: member_names,
                priority,
                created_at: at,
                state: GangState::Pending,
                reserved: Vec::new(),
                attempts: 0,
                backoff_until: 0.0,
                last_progress: at,
            },
        );
        self.gang_order.push(name.to_string());
        Ok(())
    }

    /// A gang by name (tests/views).
    pub fn gang(&self, name: &str) -> Option<&Gang> {
        self.gangs.get(name)
    }

    /// The gang a workload belongs to, if any.
    pub fn gang_of(&self, workload: &str) -> Option<&str> {
        self.gang_of.get(workload).map(String::as_str)
    }

    /// Install the decayed per-user usage snapshot consulted by the next
    /// admission pass (users absent from the map count as zero usage).
    pub fn set_fair_share(&mut self, usage: HashMap<String, f64>) {
        if self.fenced() {
            return;
        }
        self.log_op(|| KueueOp::SetFairShare { usage: usage.clone() });
        self.fair_share = usage;
    }

    /// Rebalance a ClusterQueue's nominal quota after a MIG repartition:
    /// `add` the newly advertised extended resources, `remove` the old
    /// advertisement (clamped at zero — rounding of the share split means
    /// removals may not match what was originally granted).
    pub fn adjust_nominal(
        &mut self,
        queue: &str,
        add: &ResourceVec,
        remove: &ResourceVec,
    ) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| KueueOp::AdjustNominal {
            queue: queue.to_string(),
            add: add.clone(),
            remove: remove.clone(),
        });
        let cq = self
            .cluster_queues
            .get_mut(queue)
            .ok_or_else(|| anyhow::anyhow!("unknown cluster queue {queue}"))?;
        cq.nominal.add(add);
        for (k, v) in remove.iter() {
            let cur = cq.nominal.get(k);
            cq.nominal.set(k, (cur - v).max(0));
        }
        Ok(())
    }

    /// Cohort-wide free quota available to `cq` (own free + lendable free of
    /// cohort peers, if cq can borrow).
    fn available_for(&self, cq: &ClusterQueue) -> ResourceVec {
        let mut avail = cq.free();
        if cq.can_borrow {
            if let Some(cohort) = &cq.cohort {
                for peer in self.cluster_queues.values() {
                    if peer.name != cq.name && peer.cohort.as_deref() == Some(cohort) && peer.can_lend {
                        avail.add(&peer.free());
                    }
                }
            }
        }
        avail
    }

    /// Charge `req` against `cq` first, overflowing to lendable cohort peers.
    /// Returns the primary queue charged (== cq name; peers' `used` grows too
    /// — we track the full split in `loans`).
    fn charge(&mut self, cq_name: &str, req: &ResourceVec) {
        // Greedy: take from own free first, then peers.
        let (own_free, cohort, _can_borrow) = {
            let cq = &self.cluster_queues[cq_name];
            (cq.free(), cq.cohort.clone(), cq.can_borrow)
        };
        let mut remaining = req.clone();
        let mut own_take = ResourceVec::new();
        for (k, v) in req.iter() {
            let take = v.min(own_free.get(k));
            if take > 0 {
                own_take.set(k, take);
                remaining.set(k, v - take);
            }
        }
        {
            let cq = self.cluster_queues.get_mut(cq_name).unwrap();
            cq.used.add(&own_take);
        }
        if !remaining.is_empty() {
            if let Some(cohort) = cohort {
                // sorted, not HashMap order: which peer lends first decides
                // the per-queue `used` split, and replay must reproduce it
                // byte-identically
                let mut peers: Vec<String> = self
                    .cluster_queues
                    .values()
                    .filter(|p| p.name != cq_name && p.cohort.as_deref() == Some(&cohort) && p.can_lend)
                    .map(|p| p.name.clone())
                    .collect();
                peers.sort();
                for peer_name in peers {
                    if remaining.is_empty() {
                        break;
                    }
                    let free = self.cluster_queues[&peer_name].free();
                    let mut take = ResourceVec::new();
                    for (k, v) in remaining.clone().iter() {
                        let t = v.min(free.get(k));
                        if t > 0 {
                            take.set(k, t);
                            remaining.set(k, v - t);
                        }
                    }
                    self.cluster_queues.get_mut(&peer_name).unwrap().used.add(&take);
                }
            }
        }
        debug_assert!(remaining.is_empty(), "charge exceeded cohort capacity: {remaining}");
    }

    fn uncharge(&mut self, cq_name: &str, req: &ResourceVec) {
        // Inverse of charge: release own first then peers. Since we don't
        // persist the split, release greedily from used amounts.
        let mut remaining = req.clone();
        let mut release_own = ResourceVec::new();
        {
            let cq = &self.cluster_queues[cq_name];
            for (k, v) in req.iter() {
                let r = v.min(cq.used.get(k));
                if r > 0 {
                    release_own.set(k, r);
                    remaining.set(k, v - r);
                }
            }
        }
        self.cluster_queues.get_mut(cq_name).unwrap().used.sub(&release_own);
        if !remaining.is_empty() {
            let cohort = self.cluster_queues[cq_name].cohort.clone();
            if let Some(cohort) = cohort {
                // sorted for the same replay-determinism reason as `charge`
                let mut peers: Vec<String> = self
                    .cluster_queues
                    .values()
                    .filter(|p| p.name != cq_name && p.cohort.as_deref() == Some(&cohort))
                    .map(|p| p.name.clone())
                    .collect();
                peers.sort();
                for peer in peers {
                    if remaining.is_empty() {
                        break;
                    }
                    let mut take = ResourceVec::new();
                    {
                        let p = &self.cluster_queues[&peer];
                        for (k, v) in remaining.clone().iter() {
                            let t = v.min(p.used.get(k));
                            if t > 0 {
                                take.set(k, t);
                                remaining.set(k, v - t);
                            }
                        }
                    }
                    self.cluster_queues.get_mut(&peer).unwrap().used.sub(&take);
                }
            }
        }
    }

    /// One admission pass: admit every queued workload whose quota fits —
    /// priority order, then the fair-share tiebreak (lowest decayed GPU
    /// usage first, from the snapshot installed via
    /// [`set_fair_share`](Self::set_fair_share)), then FIFO. If a
    /// high-priority workload does not fit, evict admitted lower-priority
    /// workloads (smallest sufficient set, newest first) — the paper's
    /// interactive-over-batch policy.
    pub fn admit_pass(&mut self, at: Time) -> AdmissionResult {
        if self.fenced() {
            return AdmissionResult::default();
        }
        self.log_op(|| KueueOp::AdmitPass { at });
        let mut result = AdmissionResult::default();

        // candidates: Queued or requeue-expired evicted
        let mut candidates: Vec<(i32, f64, usize, String)> = Vec::new();
        for (idx, name) in self.order.iter().enumerate() {
            // gang members never admit individually — the gang pass below
            // reserves and binds them as a unit
            if self.gang_of.contains_key(name) {
                continue;
            }
            let w = &self.workloads[name];
            let ready = match &w.state {
                WorkloadState::Queued => true,
                WorkloadState::EvictedPendingRequeue { until } => *until <= at,
                _ => false,
            };
            if ready {
                let usage = self.fair_share.get(&w.user).copied().unwrap_or(0.0);
                candidates.push((w.priority.value(), usage, idx, name.clone()));
            }
        }
        candidates.sort_by(|a, b| {
            b.0.cmp(&a.0)
                .then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.2.cmp(&b.2))
        });

        for (_, _, _, name) in candidates {
            let (queue, priority, req) = {
                let w = &self.workloads[&name];
                (w.queue.clone(), w.priority, w.requests.clone())
            };
            let cq_name = self.local_queues[&queue].cluster_queue.clone();
            let avail = self.available_for(&self.cluster_queues[&cq_name]);

            if req.fits_in(&avail) {
                self.charge(&cq_name, &req);
                let w = self.workloads.get_mut(&name).unwrap();
                w.state = WorkloadState::Admitted;
                w.admitted_at = Some(at);
                w.charged_to = Some(cq_name);
                self.log_transition(at, &name, WorkloadState::Admitted);
                result.admitted.push(name);
                continue;
            }

            // try preemption: evict lower-priority admitted workloads
            let mut victims: Vec<String> = self
                .workloads
                .values()
                .filter(|v| {
                    v.state == WorkloadState::Admitted
                        && v.priority.value() < priority.value()
                        // evicting one gang member would break the gang's
                        // all-or-nothing contract; gangs are not victims
                        && !self.gang_of.contains_key(&v.name)
                })
                .map(|v| v.name.clone())
                .collect();
            if victims.is_empty() {
                continue;
            }
            // newest admitted first (least sunk work); name tiebreak keeps
            // victim choice deterministic across runs (HashMap order isn't)
            victims.sort_by(|a, b| {
                let ta = self.workloads[a].admitted_at.unwrap_or(0.0);
                let tb = self.workloads[b].admitted_at.unwrap_or(0.0);
                tb.partial_cmp(&ta).unwrap().then_with(|| a.cmp(b))
            });

            let mut evicted_now = Vec::new();
            for victim in victims {
                // release victim's quota, back to the queue with backoff
                self.evict_to_backoff(&victim, at);
                evicted_now.push(victim.clone());
                result.preempted.push((victim, name.clone()));

                let avail = self.available_for(&self.cluster_queues[&cq_name]);
                if req.fits_in(&avail) {
                    break;
                }
            }

            let avail = self.available_for(&self.cluster_queues[&cq_name]);
            if req.fits_in(&avail) {
                self.charge(&cq_name, &req);
                let w = self.workloads.get_mut(&name).unwrap();
                w.state = WorkloadState::Admitted;
                w.admitted_at = Some(at);
                w.charged_to = Some(cq_name);
                self.log_transition(at, &name, WorkloadState::Admitted);
                result.admitted.push(name);
            }
            // note: evictions stand even if still unfit (mirrors Kueue's
            // preemption-then-retry behaviour; the evicted work requeues).
            let _ = evicted_now;
        }
        self.gang_pass(at, &mut result);
        result
    }

    /// Gang reserve → bind, run after the individual candidates. Service
    /// order is deterministic (priority desc, arrival asc, name asc).
    /// Each pending gang extends its reservation member by member; a gang
    /// whose every member holds quota binds — all members `Admitted` in
    /// this pass. Stalled partial reservations (no growth for
    /// `gang_reserve_timeout`) are fully released and the gang backs off
    /// exponentially, staggered by stall rank, so two half-admitted gangs
    /// release, desynchronize, and converge instead of starving each other.
    fn gang_pass(&mut self, at: Time, result: &mut AdmissionResult) {
        if self.gangs.is_empty() {
            return;
        }
        let mut pending: Vec<String> = self
            .gang_order
            .iter()
            .filter(|g| self.gangs[g.as_str()].state == GangState::Pending)
            .cloned()
            .collect();
        pending.sort_by(|a, b| {
            let (ga, gb) = (&self.gangs[a], &self.gangs[b]);
            gb.priority
                .value()
                .cmp(&ga.priority.value())
                .then(ga.created_at.partial_cmp(&gb.created_at).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.cmp(b))
        });
        for name in &pending {
            if self.gangs[name].backoff_until > at {
                continue;
            }
            let members = self.gangs[name].members.clone();
            let mut progressed = false;
            for m in &members {
                if self.gangs[name].reserved.contains(m) {
                    continue;
                }
                let (queue, req) = {
                    let w = &self.workloads[m];
                    (w.queue.clone(), w.requests.clone())
                };
                let cq_name = self.local_queues[&queue].cluster_queue.clone();
                let avail = self.available_for(&self.cluster_queues[&cq_name]);
                if !req.fits_in(&avail) {
                    // members reserve strictly in order: a hole in the
                    // middle stops the gang (no point grabbing the tail)
                    break;
                }
                self.charge(&cq_name, &req);
                self.workloads.get_mut(m).unwrap().charged_to = Some(cq_name);
                self.gangs.get_mut(name).unwrap().reserved.push(m.clone());
                progressed = true;
            }
            let fully_reserved = {
                let g = self.gangs.get_mut(name).unwrap();
                if progressed {
                    g.last_progress = at;
                }
                g.reserved.len() == g.members.len()
            };
            if fully_reserved {
                self.gangs.get_mut(name).unwrap().state = GangState::Bound;
                for m in members {
                    let w = self.workloads.get_mut(&m).unwrap();
                    w.state = WorkloadState::Admitted;
                    w.admitted_at = Some(at);
                    self.log_transition(at, &m, WorkloadState::Admitted);
                    result.admitted.push(m);
                }
            }
        }
        // deadlock breaker: release stalled partial reservations
        let stalled: Vec<String> = pending
            .iter()
            .filter(|g| {
                let gang = &self.gangs[g.as_str()];
                gang.state == GangState::Pending
                    && !gang.reserved.is_empty()
                    && gang.backoff_until <= at
                    && at - gang.last_progress >= self.gang_reserve_timeout
            })
            .cloned()
            .collect();
        let base = self.backoff_base.max(1.0);
        for (rank, name) in stalled.iter().enumerate() {
            let reserved = self.gangs[name].reserved.clone();
            for m in &reserved {
                let (cq, req) = {
                    let w = &self.workloads[m];
                    (w.charged_to.clone(), w.requests.clone())
                };
                if let Some(cq) = cq {
                    self.uncharge(&cq, &req);
                }
                self.workloads.get_mut(m).unwrap().charged_to = None;
            }
            let g = self.gangs.get_mut(name).unwrap();
            g.reserved.clear();
            g.attempts += 1;
            let delay = base * (1 << (g.attempts - 1).min(6)) as f64 * (rank as f64 + 1.0);
            g.backoff_until = at + delay;
            g.last_progress = at + delay;
        }
    }

    /// Release an admitted workload's quota and put it back in the queue
    /// with the exponential eviction backoff — the one eviction state
    /// machine shared by preemption and self-heal requeues.
    fn evict_to_backoff(&mut self, name: &str, at: Time) {
        let (cq, req) = {
            let w = &self.workloads[name];
            (w.charged_to.clone(), w.requests.clone())
        };
        if let Some(cq) = cq {
            self.uncharge(&cq, &req);
        }
        let backoff = self.backoff_base;
        let w = self.workloads.get_mut(name).unwrap();
        w.evictions += 1;
        let delay = backoff * (1 << (w.evictions - 1).min(6)) as f64;
        w.state = WorkloadState::EvictedPendingRequeue { until: at + delay };
        w.charged_to = None;
        let s = w.state.clone();
        self.log_transition(at, name, s);
    }

    /// Requeue an admitted workload after a pod/remote failure: same
    /// backoff machinery preemption uses. This is the self-healing
    /// controller's path back through admission — the workload re-enters
    /// the queue and, once its backoff expires, is readmitted and realized
    /// as a fresh pod incarnation (typically on a different, healthy site).
    pub fn requeue(&mut self, name: &str, at: Time) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| KueueOp::Requeue { name: name.to_string(), at });
        let state = self
            .workloads
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown workload {name}"))?
            .state
            .clone();
        anyhow::ensure!(
            state == WorkloadState::Admitted,
            "workload {name} not admitted (state {state:?})"
        );
        anyhow::ensure!(
            !self.gang_of.contains_key(name),
            "workload {name} is a gang member; finish the whole gang instead"
        );
        self.evict_to_backoff(name, at);
        Ok(())
    }

    /// Mark a workload finished and release its quota.
    pub fn finish(&mut self, name: &str, at: Time) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| KueueOp::Finish { name: name.to_string(), at });
        let (state, cq, req) = {
            let w = self
                .workloads
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown workload {name}"))?;
            (w.state.clone(), w.charged_to.clone(), w.requests.clone())
        };
        if state == WorkloadState::Finished {
            return Ok(()); // idempotent: no duplicate transition logged
        }
        // any held charge is released — covers admitted workloads and gang
        // members whose quota was reserved but never bound (stage cancel)
        if let Some(cq) = cq {
            self.uncharge(&cq, &req);
        }
        let w = self.workloads.get_mut(name).unwrap();
        w.state = WorkloadState::Finished;
        w.charged_to = None;
        self.log_transition(at, name, WorkloadState::Finished);
        // gang bookkeeping: drop the member's reservation entry; the gang
        // is finished once its last member is
        if let Some(gang) = self.gang_of.get(name).cloned() {
            let all_done = {
                let g = self.gangs.get_mut(&gang).expect("gang exists for member");
                g.reserved.retain(|m| m != name);
                g.members.iter().all(|m| {
                    self.workloads.get(m).map(|w| w.state == WorkloadState::Finished).unwrap_or(true)
                })
            };
            if all_done {
                self.gangs.get_mut(&gang).unwrap().state = GangState::Finished;
            }
        }
        Ok(())
    }

    /// Queue wait time for admitted/finished workloads.
    pub fn wait_time(&self, name: &str) -> Option<Time> {
        let w = self.workloads.get(name)?;
        Some(w.admitted_at? - w.created_at)
    }

    /// Total used vs nominal across cluster queues (utilization metric).
    pub fn quota_utilization(&self) -> (ResourceVec, ResourceVec) {
        let mut used = ResourceVec::new();
        let mut nominal = ResourceVec::new();
        for cq in self.cluster_queues.values() {
            used.add(&cq.used);
            nominal.add(&cq.nominal);
        }
        (used, nominal)
    }
}

// --------------------------------------------------------------- durability

impl Enc for PriorityClass {
    fn enc(&self, b: &mut Vec<u8>) {
        let tag: u8 = match self {
            PriorityClass::Batch => 0,
            PriorityClass::BatchHigh => 1,
            PriorityClass::Interactive => 2,
        };
        b.push(tag);
    }
}

impl Dec for PriorityClass {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => PriorityClass::Batch,
            1 => PriorityClass::BatchHigh,
            2 => PriorityClass::Interactive,
            t => return Err(CodecError(format!("bad priority class tag {t}"))),
        })
    }
}

impl Enc for WorkloadState {
    fn enc(&self, b: &mut Vec<u8>) {
        match self {
            WorkloadState::Queued => b.push(0),
            WorkloadState::Admitted => b.push(1),
            WorkloadState::EvictedPendingRequeue { until } => {
                b.push(2);
                until.enc(b);
            }
            WorkloadState::Finished => b.push(3),
        }
    }
}

impl Dec for WorkloadState {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => WorkloadState::Queued,
            1 => WorkloadState::Admitted,
            2 => WorkloadState::EvictedPendingRequeue { until: Dec::dec(r)? },
            3 => WorkloadState::Finished,
            t => return Err(CodecError(format!("bad workload state tag {t}"))),
        })
    }
}

impl Enc for Workload {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.queue.enc(b);
        self.priority.enc(b);
        self.requests.enc(b);
        self.state.enc(b);
        self.created_at.enc(b);
        self.admitted_at.enc(b);
        self.evictions.enc(b);
        self.charged_to.enc(b);
        self.user.enc(b);
    }
}

impl Dec for Workload {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Workload {
            name: Dec::dec(r)?,
            queue: Dec::dec(r)?,
            priority: Dec::dec(r)?,
            requests: Dec::dec(r)?,
            state: Dec::dec(r)?,
            created_at: Dec::dec(r)?,
            admitted_at: Dec::dec(r)?,
            evictions: Dec::dec(r)?,
            charged_to: Dec::dec(r)?,
            user: Dec::dec(r)?,
        })
    }
}

impl Enc for ClusterQueue {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.cohort.enc(b);
        self.nominal.enc(b);
        self.used.enc(b);
        self.can_borrow.enc(b);
        self.can_lend.enc(b);
    }
}

impl Dec for ClusterQueue {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(ClusterQueue {
            name: Dec::dec(r)?,
            cohort: Dec::dec(r)?,
            nominal: Dec::dec(r)?,
            used: Dec::dec(r)?,
            can_borrow: Dec::dec(r)?,
            can_lend: Dec::dec(r)?,
        })
    }
}

impl Enc for LocalQueue {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.cluster_queue.enc(b);
    }
}

impl Dec for LocalQueue {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(LocalQueue { name: Dec::dec(r)?, cluster_queue: Dec::dec(r)? })
    }
}

impl Enc for WorkloadTransition {
    fn enc(&self, b: &mut Vec<u8>) {
        self.at.enc(b);
        self.workload.enc(b);
        self.state.enc(b);
    }
}

impl Dec for WorkloadTransition {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(WorkloadTransition { at: Dec::dec(r)?, workload: Dec::dec(r)?, state: Dec::dec(r)? })
    }
}

impl Enc for GangState {
    fn enc(&self, b: &mut Vec<u8>) {
        b.push(match self {
            GangState::Pending => 0,
            GangState::Bound => 1,
            GangState::Finished => 2,
        });
    }
}

impl Dec for GangState {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => GangState::Pending,
            1 => GangState::Bound,
            2 => GangState::Finished,
            t => return Err(CodecError(format!("bad gang state tag {t}"))),
        })
    }
}

impl Enc for Gang {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.members.enc(b);
        self.priority.enc(b);
        self.created_at.enc(b);
        self.state.enc(b);
        self.reserved.enc(b);
        self.attempts.enc(b);
        self.backoff_until.enc(b);
        self.last_progress.enc(b);
    }
}

impl Dec for Gang {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Gang {
            name: Dec::dec(r)?,
            members: Dec::dec(r)?,
            priority: Dec::dec(r)?,
            created_at: Dec::dec(r)?,
            state: Dec::dec(r)?,
            reserved: Dec::dec(r)?,
            attempts: Dec::dec(r)?,
            backoff_until: Dec::dec(r)?,
            last_progress: Dec::dec(r)?,
        })
    }
}

/// Kueue snapshots encode the whole controller state — unlike the store
/// there is no derived structure to rebuild; the maps *are* the state.
impl Enc for Kueue {
    fn enc(&self, b: &mut Vec<u8>) {
        self.cluster_queues.enc(b);
        self.local_queues.enc(b);
        self.workloads.enc(b);
        self.order.enc(b);
        self.transitions.enc(b);
        self.backoff_base.enc(b);
        self.fair_share.enc(b);
        self.gangs.enc(b);
        self.gang_order.enc(b);
        self.gang_of.enc(b);
        self.gang_reserve_timeout.enc(b);
    }
}

impl Dec for Kueue {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Kueue {
            cluster_queues: Dec::dec(r)?,
            local_queues: Dec::dec(r)?,
            workloads: Dec::dec(r)?,
            order: Dec::dec(r)?,
            transitions: Dec::dec(r)?,
            backoff_base: Dec::dec(r)?,
            fair_share: Dec::dec(r)?,
            gangs: Dec::dec(r)?,
            gang_order: Dec::dec(r)?,
            gang_of: Dec::dec(r)?,
            gang_reserve_timeout: Dec::dec(r)?,
            wal: None,
            writer_epoch: 0,
            fenced_below: 0,
            fenced_writes: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::resources::{ResourceVec, CPU, GPU};

    fn rv(cpu: i64, gpu: i64) -> ResourceVec {
        let mut r = ResourceVec::cpu_millis(cpu);
        if gpu > 0 {
            r.set(GPU, gpu);
        }
        r
    }

    /// Two queues in one cohort: interactive (lends, never borrows-needy)
    /// and batch (borrows).
    fn kueue() -> Kueue {
        let mut k = Kueue::new();
        k.add_cluster_queue(ClusterQueue {
            name: "interactive-cq".into(),
            cohort: Some("ai-infn".into()),
            nominal: rv(16_000, 4),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: true,
        });
        k.add_cluster_queue(ClusterQueue {
            name: "batch-cq".into(),
            cohort: Some("ai-infn".into()),
            nominal: rv(8_000, 2),
            used: ResourceVec::new(),
            can_borrow: true,
            can_lend: false,
        });
        k.add_local_queue(LocalQueue { name: "hub".into(), cluster_queue: "interactive-cq".into() });
        k.add_local_queue(LocalQueue { name: "batch".into(), cluster_queue: "batch-cq".into() });
        k
    }

    #[test]
    fn admits_within_quota_fifo() {
        let mut k = kueue();
        k.submit("w1", "batch", PriorityClass::Batch, rv(4000, 1), 0.0).unwrap();
        k.submit("w2", "batch", PriorityClass::Batch, rv(4000, 1), 1.0).unwrap();
        k.submit("w3", "batch", PriorityClass::Batch, rv(4000, 1), 2.0).unwrap();
        let r = k.admit_pass(10.0);
        // batch nominal = 8000/2gpu; w3 borrows from interactive (idle 4 GPUs)
        assert_eq!(r.admitted.len(), 3);
        assert_eq!(k.wait_time("w1"), Some(10.0));
    }

    #[test]
    fn borrowing_stops_when_cohort_exhausted() {
        let mut k = kueue();
        // 6 GPU jobs: 2 own + 4 borrowed = 6 admitted, 7th waits
        for i in 0..7 {
            k.submit(format!("w{i}"), "batch", PriorityClass::Batch, rv(1000, 1), 0.0).unwrap();
        }
        let r = k.admit_pass(0.0);
        assert_eq!(r.admitted.len(), 6);
        assert_eq!(
            k.workload("w6").unwrap().state,
            WorkloadState::Queued
        );
    }

    #[test]
    fn interactive_preempts_batch_on_contention() {
        let mut k = kueue();
        // batch borrows everything
        for i in 0..6 {
            k.submit(format!("b{i}"), "batch", PriorityClass::Batch, rv(1000, 1), 0.0).unwrap();
        }
        assert_eq!(k.admit_pass(0.0).admitted.len(), 6);
        // an interactive session arrives needing 2 GPUs
        k.submit("sess", "hub", PriorityClass::Interactive, rv(2000, 2), 100.0).unwrap();
        let r = k.admit_pass(100.0);
        assert!(r.admitted.contains(&"sess".to_string()));
        assert!(!r.preempted.is_empty(), "batch jobs must be evicted");
        // victims are newest-admitted batch jobs, with backoff requeue
        for (victim, preemptor) in &r.preempted {
            assert_eq!(preemptor, "sess");
            match k.workload(victim).unwrap().state {
                WorkloadState::EvictedPendingRequeue { until } => assert!(until > 100.0),
                ref s => panic!("victim state {s:?}"),
            }
        }
    }

    #[test]
    fn batch_never_preempts_interactive() {
        let mut k = kueue();
        // interactive fills its own quota
        for i in 0..4 {
            k.submit(format!("s{i}"), "hub", PriorityClass::Interactive, rv(4000, 1), 0.0).unwrap();
        }
        assert_eq!(k.admit_pass(0.0).admitted.len(), 4);
        // batch wants a GPU that only interactive quota could provide
        k.submit("b0", "batch", PriorityClass::Batch, rv(1000, 3), 1.0).unwrap();
        let r = k.admit_pass(1.0);
        assert!(r.admitted.is_empty());
        assert!(r.preempted.is_empty(), "batch must never evict interactive");
    }

    #[test]
    fn evicted_workload_requeues_after_backoff() {
        let mut k = kueue();
        for i in 0..6 {
            k.submit(format!("b{i}"), "batch", PriorityClass::Batch, rv(1000, 1), 0.0).unwrap();
        }
        k.admit_pass(0.0);
        k.submit("sess", "hub", PriorityClass::Interactive, rv(2000, 4), 10.0).unwrap();
        let r = k.admit_pass(10.0);
        let victim = r.preempted[0].0.clone();
        // before backoff expiry: not admitted
        let r2 = k.admit_pass(11.0);
        assert!(!r2.admitted.contains(&victim));
        // finish the interactive session, wait out backoff → readmitted
        k.finish("sess", 100.0).unwrap();
        let r3 = k.admit_pass(10.0 + 31.0);
        assert!(r3.admitted.contains(&victim), "{r3:?}");
    }

    #[test]
    fn finish_releases_quota_conservation_invariant() {
        let mut k = kueue();
        k.submit("w1", "batch", PriorityClass::Batch, rv(8000, 2), 0.0).unwrap();
        k.admit_pass(0.0);
        let (used, _) = k.quota_utilization();
        assert_eq!(used.get(CPU), 8000);
        k.finish("w1", 1.0).unwrap();
        let (used, _) = k.quota_utilization();
        assert!(used.is_empty());
    }

    #[test]
    fn borrow_charge_splits_across_queues() {
        let mut k = kueue();
        // 3 GPUs: 2 from batch quota + 1 borrowed from interactive
        k.submit("w1", "batch", PriorityClass::Batch, rv(1000, 3), 0.0).unwrap();
        k.admit_pass(0.0);
        assert_eq!(k.cluster_queue("batch-cq").unwrap().used.get(GPU), 2);
        assert_eq!(k.cluster_queue("interactive-cq").unwrap().used.get(GPU), 1);
        // release restores both
        k.finish("w1", 1.0).unwrap();
        assert_eq!(k.cluster_queue("batch-cq").unwrap().used.get(GPU), 0);
        assert_eq!(k.cluster_queue("interactive-cq").unwrap().used.get(GPU), 0);
    }

    #[test]
    fn requeue_releases_quota_and_backs_off() {
        let mut k = kueue();
        k.submit("w1", "batch", PriorityClass::Batch, rv(8000, 2), 0.0).unwrap();
        k.admit_pass(0.0);
        assert_eq!(k.workload("w1").unwrap().state, WorkloadState::Admitted);
        k.requeue("w1", 10.0).unwrap();
        // quota released immediately
        let (used, _) = k.quota_utilization();
        assert!(used.is_empty(), "{used}");
        match k.workload("w1").unwrap().state {
            WorkloadState::EvictedPendingRequeue { until } => {
                assert!((until - 40.0).abs() < 1e-9, "30s base backoff: {until}")
            }
            ref s => panic!("state {s:?}"),
        }
        // not admitted before the backoff expires
        assert!(!k.admit_pass(20.0).admitted.contains(&"w1".to_string()));
        // readmitted after it, with a doubled backoff on the next requeue
        assert!(k.admit_pass(41.0).admitted.contains(&"w1".to_string()));
        k.requeue("w1", 50.0).unwrap();
        match k.workload("w1").unwrap().state {
            WorkloadState::EvictedPendingRequeue { until } => {
                assert!((until - 110.0).abs() < 1e-9, "60s doubled backoff: {until}")
            }
            ref s => panic!("state {s:?}"),
        }
        // requeueing a non-admitted workload is an error
        assert!(k.requeue("w1", 60.0).is_err());
    }

    #[test]
    fn fair_share_breaks_ties_within_priority_band() {
        let mut k = kueue();
        // one GPU of quota headroom at a time: admission order matters
        k.submit_for_user("heavy", "batch", "alice", PriorityClass::Batch, rv(1000, 6), 0.0)
            .unwrap();
        k.submit_for_user("light", "batch", "bob", PriorityClass::Batch, rv(1000, 6), 1.0)
            .unwrap();
        // alice has burned GPU-hours recently, bob has not: bob goes first
        // despite arriving later
        let mut usage = std::collections::HashMap::new();
        usage.insert("alice".to_string(), 12.0);
        usage.insert("bob".to_string(), 0.5);
        k.set_fair_share(usage);
        let r = k.admit_pass(2.0);
        assert_eq!(r.admitted, vec!["light".to_string()]);
        // priority still dominates usage: an interactive session from the
        // heaviest user beats every batch peer
        k.submit_for_user("sess", "hub", "alice", PriorityClass::Interactive, rv(1000, 1), 3.0)
            .unwrap();
        let r2 = k.admit_pass(3.0);
        assert!(r2.admitted.contains(&"sess".to_string()));
        // unattributed workloads (empty user) keep plain FIFO among
        // themselves
        let mut k2 = kueue();
        k2.submit("w1", "batch", PriorityClass::Batch, rv(1000, 0), 0.0).unwrap();
        k2.submit("w2", "batch", PriorityClass::Batch, rv(1000, 0), 1.0).unwrap();
        assert_eq!(k2.admit_pass(2.0).admitted, vec!["w1".to_string(), "w2".to_string()]);
    }

    #[test]
    fn adjust_nominal_adds_removes_and_clamps() {
        let mut k = kueue();
        let add = ResourceVec::new().with("nvidia.com/mig-1g.5gb", 7);
        let remove = ResourceVec::new().with(GPU, 3); // more than nominal: clamps
        k.adjust_nominal("batch-cq", &add, &remove).unwrap();
        let cq = k.cluster_queue("batch-cq").unwrap();
        assert_eq!(cq.nominal.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(cq.nominal.get(GPU), 0);
        assert!(k.adjust_nominal("ghost", &add, &remove).is_err());
    }

    #[test]
    fn duplicate_and_unknown_queue_rejected() {
        let mut k = kueue();
        k.submit("w", "batch", PriorityClass::Batch, rv(1, 0), 0.0).unwrap();
        assert!(k.submit("w", "batch", PriorityClass::Batch, rv(1, 0), 0.0).is_err());
        assert!(k.submit("x", "nope", PriorityClass::Batch, rv(1, 0), 0.0).is_err());
    }

    fn gang_members(prefix: &str, n: usize, gpus: i64) -> Vec<(String, ResourceVec)> {
        (0..n).map(|i| (format!("{prefix}-p{i}"), rv(1000, gpus))).collect()
    }

    #[test]
    fn gang_binds_all_or_nothing() {
        let mut k = kueue();
        // cohort GPU capacity = 2 (batch) + 4 (interactive) = 6
        k.submit_gang("g1", "batch", "alice", PriorityClass::Batch, gang_members("g1", 3, 2), 0.0)
            .unwrap();
        let r = k.admit_pass(0.0);
        assert_eq!(r.admitted.len(), 3, "{r:?}");
        assert_eq!(k.gang("g1").unwrap().state, GangState::Bound);
        // a gang that cannot fully fit reserves nothing schedulable: every
        // member stays Queued even though capacity would cover a subset
        k.submit_gang("g2", "batch", "bob", PriorityClass::Batch, gang_members("g2", 4, 2), 1.0)
            .unwrap();
        let r2 = k.admit_pass(1.0);
        assert!(r2.admitted.is_empty(), "{r2:?}");
        for i in 0..4 {
            assert_eq!(k.workload(&format!("g2-p{i}")).unwrap().state, WorkloadState::Queued);
        }
    }

    #[test]
    fn gang_finish_releases_all_quota() {
        let mut k = kueue();
        k.submit_gang("g1", "batch", "alice", PriorityClass::Batch, gang_members("g1", 3, 2), 0.0)
            .unwrap();
        k.admit_pass(0.0);
        let (used, _) = k.quota_utilization();
        assert_eq!(used.get(GPU), 6);
        for i in 0..3 {
            k.finish(&format!("g1-p{i}"), 10.0).unwrap();
        }
        assert_eq!(k.gang("g1").unwrap().state, GangState::Finished);
        let (used, _) = k.quota_utilization();
        assert!(used.is_empty(), "{used}");
    }

    #[test]
    fn gang_members_are_never_preemption_victims() {
        let mut k = kueue();
        k.submit_gang("g1", "batch", "alice", PriorityClass::Batch, gang_members("g1", 3, 2), 0.0)
            .unwrap();
        k.admit_pass(0.0);
        // an interactive arrival that would need gang quota cannot evict it
        k.submit("sess", "hub", PriorityClass::Interactive, rv(2000, 2), 5.0).unwrap();
        let r = k.admit_pass(5.0);
        assert!(r.preempted.is_empty(), "gang members must not be evicted: {r:?}");
        assert!(!r.admitted.contains(&"sess".to_string()));
    }

    #[test]
    fn two_stalled_gangs_release_desynchronize_and_converge() {
        let mut k = Kueue::new();
        k.gang_reserve_timeout = 60.0;
        k.add_cluster_queue(ClusterQueue {
            name: "wf-cq".into(),
            cohort: None,
            nominal: rv(64_000, 8),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: false,
        });
        k.add_local_queue(LocalQueue { name: "wf".into(), cluster_queue: "wf-cq".into() });
        // a regular workload occupies 2 GPUs so neither gang fully fits
        k.submit("filler", "wf", PriorityClass::Batch, rv(1000, 2), 0.0).unwrap();
        k.admit_pass(0.0);
        // gang A: 2×4 GPUs (needs 8, 6 free) — reserves one member
        // gang B: 2×2 GPUs (needs 4, 2 free after A) — reserves one member
        k.submit_gang("ga", "wf", "alice", PriorityClass::Batch, gang_members("ga", 2, 4), 1.0)
            .unwrap();
        k.submit_gang("gb", "wf", "bob", PriorityClass::Batch, gang_members("gb", 2, 2), 2.0)
            .unwrap();
        let r = k.admit_pass(2.0);
        assert!(r.admitted.is_empty());
        assert_eq!(k.gang("ga").unwrap().reserved.len(), 1, "half-admitted");
        assert_eq!(k.gang("gb").unwrap().reserved.len(), 1, "half-admitted");
        let (used, _) = k.quota_utilization();
        assert_eq!(used.get(GPU), 2 + 4 + 2);
        // stall timeout: both release their partial reservations, with
        // rank-staggered backoff (ga retries at +30, gb at +60)
        let r2 = k.admit_pass(62.0);
        assert!(r2.admitted.is_empty());
        assert!(k.gang("ga").unwrap().reserved.is_empty());
        assert!(k.gang("gb").unwrap().reserved.is_empty());
        let (used, _) = k.quota_utilization();
        assert_eq!(used.get(GPU), 2, "only the filler holds quota");
        assert!(k.gang("gb").unwrap().backoff_until > k.gang("ga").unwrap().backoff_until);
        // the filler finishes; ga's backoff expires first and it binds
        k.finish("filler", 70.0).unwrap();
        let r3 = k.admit_pass(93.0);
        assert_eq!(r3.admitted.len(), 2, "{r3:?}");
        assert_eq!(k.gang("ga").unwrap().state, GangState::Bound);
        // ga completes; gb converges on a later pass
        k.finish("ga-p0", 100.0).unwrap();
        k.finish("ga-p1", 100.0).unwrap();
        let r4 = k.admit_pass(130.0);
        assert_eq!(r4.admitted.len(), 2, "{r4:?}");
        assert_eq!(k.gang("gb").unwrap().state, GangState::Bound);
        for w in ["ga-p0", "ga-p1", "gb-p0", "gb-p1", "filler"] {
            let s = &k.workload(w).unwrap().state;
            assert!(
                matches!(s, WorkloadState::Admitted | WorkloadState::Finished),
                "no workload lost: {w} is {s:?}"
            );
        }
        k.finish("gb-p0", 140.0).unwrap();
        k.finish("gb-p1", 140.0).unwrap();
        let (used, _) = k.quota_utilization();
        assert!(used.is_empty(), "quotas drain: {used}");
    }

    #[test]
    fn gang_state_survives_snapshot_and_wal_replay() {
        use crate::cluster::wal::{Wal, WalRecord};
        let wal = Wal::shared();
        let mut k = Kueue::new();
        k.gang_reserve_timeout = 45.0;
        k.attach_wal(wal.clone());
        k.add_cluster_queue(ClusterQueue {
            name: "wf-cq".into(),
            cohort: None,
            nominal: rv(64_000, 4),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: false,
        });
        k.add_local_queue(LocalQueue { name: "wf".into(), cluster_queue: "wf-cq".into() });
        k.submit_gang("g1", "wf", "alice", PriorityClass::Batch, gang_members("g1", 2, 2), 0.0)
            .unwrap();
        k.admit_pass(0.0); // binds
        k.submit_gang("g2", "wf", "bob", PriorityClass::BatchHigh, gang_members("g2", 2, 2), 1.0)
            .unwrap();
        k.admit_pass(1.0); // g2 partial-reserves
        k.finish("g1-p0", 5.0).unwrap();
        // snapshot round-trip is byte-identical with gang state present
        let bytes = k.to_bytes();
        let restored = Kueue::from_bytes(&bytes).unwrap();
        assert_eq!(restored.to_bytes(), bytes);
        assert_eq!(restored.gang("g1").unwrap().state, GangState::Bound);
        assert_eq!(restored.gang("g2").unwrap().reserved, k.gang("g2").unwrap().reserved);
        // wal replay reproduces the same bytes on a fresh controller
        let (records, warn) = wal.borrow().replay();
        assert!(warn.is_none(), "{warn:?}");
        let mut replayed = Kueue::new();
        replayed.gang_reserve_timeout = 45.0;
        for rec in records {
            match rec {
                WalRecord::Kueue(op) => replayed.apply_op(op),
                other => panic!("kueue-only log, got {other:?}"),
            }
        }
        k.detach_wal();
        assert_eq!(replayed.to_bytes(), k.to_bytes());
    }

    #[test]
    fn snapshot_roundtrip_is_byte_identical() {
        let mut k = kueue();
        for i in 0..6 {
            k.submit(format!("b{i}"), "batch", PriorityClass::Batch, rv(1000, 1), 0.0).unwrap();
        }
        k.admit_pass(0.0);
        k.submit("sess", "hub", PriorityClass::Interactive, rv(2000, 4), 10.0).unwrap();
        k.admit_pass(10.0);
        k.finish("b0", 20.0).ok();
        let bytes = k.to_bytes();
        let restored = Kueue::from_bytes(&bytes).unwrap();
        assert_eq!(restored.to_bytes(), bytes, "re-encode is byte-identical");
        assert_eq!(restored.transition_cursor(), k.transition_cursor());
        let (used, nominal) = restored.quota_utilization();
        let (used0, nominal0) = k.quota_utilization();
        assert_eq!(used, used0);
        assert_eq!(nominal, nominal0);
    }

    #[test]
    fn wal_replay_reproduces_kueue_state() {
        use crate::cluster::wal::{Wal, WalRecord};
        let wal = Wal::shared();
        // attach before building the queue topology so the log covers
        // everything a fresh controller needs to reach the same state
        let mut k = Kueue::new();
        k.attach_wal(wal.clone());
        k.add_cluster_queue(ClusterQueue {
            name: "interactive-cq".into(),
            cohort: Some("ai-infn".into()),
            nominal: rv(16_000, 4),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: true,
        });
        k.add_cluster_queue(ClusterQueue {
            name: "batch-cq".into(),
            cohort: Some("ai-infn".into()),
            nominal: rv(8_000, 2),
            used: ResourceVec::new(),
            can_borrow: true,
            can_lend: false,
        });
        k.add_local_queue(LocalQueue { name: "hub".into(), cluster_queue: "interactive-cq".into() });
        k.add_local_queue(LocalQueue { name: "batch".into(), cluster_queue: "batch-cq".into() });
        // preemption + borrowing: the per-queue `used` split exercises the
        // sorted-peer charge/uncharge order replay depends on
        for i in 0..6 {
            k.submit(format!("b{i}"), "batch", PriorityClass::Batch, rv(1000, 1), 0.0).unwrap();
        }
        k.admit_pass(0.0);
        k.submit("sess", "hub", PriorityClass::Interactive, rv(2000, 4), 10.0).unwrap();
        let mut usage = std::collections::HashMap::new();
        usage.insert("alice".to_string(), 3.0);
        k.set_fair_share(usage);
        k.admit_pass(10.0);
        assert!(k.submit("b0", "batch", PriorityClass::Batch, rv(1, 0), 11.0).is_err());
        k.requeue("sess", 12.0).unwrap();
        k.finish("b1", 13.0).ok();
        k.set_transition_capacity(512);

        let (records, warn) = wal.borrow().replay();
        assert!(warn.is_none(), "{warn:?}");
        // replay onto a fresh controller with the same construction state
        let mut replayed = Kueue::new();
        for rec in records {
            match rec {
                WalRecord::Kueue(op) => replayed.apply_op(op),
                other => panic!("kueue-only log, got {other:?}"),
            }
        }
        k.detach_wal();
        assert_eq!(replayed.to_bytes(), k.to_bytes(), "replayed state byte-identical");
    }
}
