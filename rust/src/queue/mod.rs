//! Kueue-like batch queueing substrate (DESIGN.md S12): quota admission,
//! cohort borrowing, and the interactive-over-batch preemption policy the
//! paper describes in §3.

pub mod kueue;

pub use kueue::{AdmissionResult, ClusterQueue, Kueue, LocalQueue, PriorityClass, Workload, WorkloadState};
