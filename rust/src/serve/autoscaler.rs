//! Latency-aware replica autoscaling: a deterministic policy from TSDB
//! signals to a desired replica count.
//!
//! Signals come from the monitoring TSDB, not from the balancer directly —
//! the autoscaler sees exactly what a dashboard sees (p95 over the scale
//! interval, instantaneous queue depth, mean arrival rate), so the loop
//! stays honest about observability lag. The policy:
//!
//! * **rate sizing** — enough replicas to run at `target_utilization` of
//!   saturated batch throughput against the observed arrival rate;
//! * **queue drain** — enough extra capacity to drain the standing queue
//!   within the SLO budget (this is what reacts to a burst before p95
//!   climbs, and what triggers scale-from-zero: a cold backlog shows up as
//!   queue depth);
//! * **SLO breach** — observed p95 above the SLO forces at least one step
//!   up from the current count even if rate math says otherwise;
//! * **scale-to-zero** — no arrivals and no queued work for `idle_grace`
//!   seconds collapses the fleet to `min_replicas` (zero if allowed);
//! * **hysteresis** — downscales are deferred while p95 sits above half
//!   the SLO, so a fleet that is barely keeping up isn't shrunk.
//!
//! The result is clamped to `[min_replicas, max_replicas]` — and because
//! any nonzero rate sizes to ≥ 1, replicas never drop below the floor
//! while traffic is flowing.

use crate::sim::clock::Time;

use super::ServingSpec;

/// Platform-level autoscaling knobs (`serving.*` config section).
#[derive(Debug, Clone, Copy)]
pub struct ScalePolicy {
    /// Fraction of saturated throughput to size for (headroom above it
    /// absorbs arrival noise without queueing).
    pub target_utilization: f64,
    /// Seconds of no-traffic-no-queue before collapsing to `min_replicas`.
    pub idle_grace: Time,
    /// Seconds between autoscale evaluations.
    pub scale_interval: Time,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        ScalePolicy { target_utilization: 0.7, idle_grace: 300.0, scale_interval: 30.0 }
    }
}

/// Observed signals for one evaluation (read from the TSDB).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScaleSignals {
    /// Worst window p95 over the last scale interval, if any window
    /// completed requests ([`None`] ⇒ no latency data — sparse series).
    pub p95: Option<f64>,
    /// Standing queue (replica queues + balancer backlog).
    pub queue_depth: f64,
    /// Mean arrivals/second over the last scale interval.
    pub arrival_rate: f64,
    /// Current replica count (all phases).
    pub current: u32,
    /// Seconds since the server last saw arrivals or queued work.
    pub idle_for: Time,
}

/// The policy function: desired replica count for one server.
pub fn desired_replicas(spec: &ServingSpec, policy: &ScalePolicy, sig: &ScaleSignals) -> u32 {
    let mu = spec.service_rate(); // per-replica req/s at saturation
    let util = policy.target_utilization.clamp(0.05, 1.0);

    // Capacity to carry the offered rate at target utilization...
    let mut capacity = sig.arrival_rate / (mu * util);
    // ...plus capacity to drain the standing queue within the SLO budget
    // (never tighter than one batch service time).
    let slo_budget = spec.latency_slo.max(spec.service_time);
    capacity += sig.queue_depth / (mu * slo_budget);
    let mut need = capacity.ceil() as u32;

    // A breached SLO forces a step up even when rate math disagrees.
    if sig.p95.map(|p| p > spec.latency_slo).unwrap_or(false) {
        need = need.max(sig.current.saturating_add(1));
    }

    let idle = sig.arrival_rate <= 0.0 && sig.queue_depth <= 0.0;
    if idle {
        if sig.idle_for >= policy.idle_grace {
            // Scale to the floor (zero if the spec allows it).
            return spec.min_replicas.min(spec.max_replicas);
        }
        // Inside the grace window: keep one replica warm (if any exist) so
        // a brief lull doesn't pay the cold-start penalty.
        need = need.max(sig.current.min(1));
    }

    // Hysteresis: don't shrink a fleet that is barely inside the SLO.
    if need < sig.current && sig.p95.map(|p| p > 0.5 * spec.latency_slo).unwrap_or(false) {
        need = sig.current;
    }

    need.clamp(spec.min_replicas, spec.max_replicas)
}

#[cfg(test)]
mod tests {
    use super::super::tests::spec;
    use super::*;

    // spec("m"): max_batch 8, service_time 0.08 ⇒ mu = 100 req/s; slo 0.5;
    // min 0, max 4.

    fn pol() -> ScalePolicy {
        ScalePolicy::default()
    }

    #[test]
    fn sizes_to_rate_over_target_utilization() {
        let s = spec("m");
        let sig = ScaleSignals { arrival_rate: 140.0, current: 1, ..Default::default() };
        // 140 / (100 * 0.7) = 2.0 ⇒ 2 replicas.
        assert_eq!(desired_replicas(&s, &pol(), &sig), 2);
    }

    #[test]
    fn queue_pressure_adds_capacity() {
        let s = spec("m");
        // 300 queued, budget 0.5 s at 100/s ⇒ 6 replicas worth of drain,
        // clamped to max 4. This is the scale-from-zero path: a cold
        // backlog is pure queue depth with zero measured rate.
        let sig = ScaleSignals { queue_depth: 300.0, current: 0, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &sig), 4);
    }

    #[test]
    fn slo_breach_forces_step_up() {
        let s = spec("m");
        let sig = ScaleSignals {
            p95: Some(0.9),
            arrival_rate: 30.0, // rate math alone says 1
            current: 2,
            ..Default::default()
        };
        assert_eq!(desired_replicas(&s, &pol(), &sig), 3);
    }

    #[test]
    fn scale_to_zero_after_idle_grace_only() {
        let s = spec("m");
        // Idle but inside the grace window: one replica stays warm.
        let early = ScaleSignals { current: 2, idle_for: 120.0, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &early), 1);
        // Grace expired: collapse to the floor (zero here; min wins else).
        let late = ScaleSignals { current: 2, idle_for: 600.0, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &late), 0);
        let mut floored = spec("m");
        floored.min_replicas = 1;
        assert_eq!(desired_replicas(&floored, &pol(), &early), 1);
        assert_eq!(desired_replicas(&floored, &pol(), &late), 1);
        // A server that never had replicas isn't spun up by idleness.
        let never = ScaleSignals { current: 0, idle_for: 120.0, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &never), 0);
    }

    #[test]
    fn never_below_floor_while_traffic_flows() {
        let s = spec("m");
        for rate in [0.1, 1.0, 50.0, 500.0] {
            let sig = ScaleSignals { arrival_rate: rate, current: 0, ..Default::default() };
            let d = desired_replicas(&s, &pol(), &sig);
            assert!(d >= 1, "rate={rate} desired={d}");
            assert!(d <= s.max_replicas);
        }
    }

    #[test]
    fn hysteresis_defers_shrink_near_slo() {
        let s = spec("m");
        // Rate says 1 replica, but p95 is at 0.6×SLO: hold at current.
        let sig = ScaleSignals {
            p95: Some(0.3),
            arrival_rate: 30.0,
            current: 3,
            ..Default::default()
        };
        assert_eq!(desired_replicas(&s, &pol(), &sig), 3);
        // Comfortably inside SLO ⇒ the shrink goes through.
        let calm = ScaleSignals { p95: Some(0.1), arrival_rate: 30.0, current: 3, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &calm), 1);
    }

    #[test]
    fn clamped_to_max() {
        let s = spec("m");
        let sig = ScaleSignals { arrival_rate: 10_000.0, current: 4, ..Default::default() };
        assert_eq!(desired_replicas(&s, &pol(), &sig), 4);
    }
}
