//! Least-outstanding-requests load balancing with bounded queues and
//! request batching, in aggregate (fluid) form.
//!
//! Once per reconciliation tick the controller hands the balancer the
//! window's arrival count. [`step_window`] then:
//!
//! 1. **water-fills** arrivals (plus any cold-start backlog) over the
//!    ready replicas, least-outstanding first — the continuous limit of
//!    per-request least-outstanding-requests routing;
//! 2. **serves** each replica's queue against its batch capacity for the
//!    window (`max_batch / service_time` requests/second, with fractional
//!    capacity carried between windows so short ticks don't starve);
//! 3. **bounds** each queue at `queue_depth`, counting overflow as *shed* —
//!    requests are never silently dropped, they land in
//!    `failed_requests`;
//! 4. **recovers latency** analytically: queue wait at head/tail of the
//!    window, batch fill wait (the batch-size-vs-latency knob: a larger
//!    `batch_window` trades latency for throughput), the service time
//!    itself, and — for requests that sat in the zero-replica backlog —
//!    the cold-start wait, recorded into the cumulative and per-window
//!    histograms via `Histogram::record_n`.
//!
//! Everything is integer/float arithmetic over sorted maps: no RNG, no
//! hash iteration — the same inputs always produce the same report, which
//! golden-trace tests rely on.

use crate::sim::clock::Time;

use super::{ReplicaPhase, ServerState};

/// What one balancer window did (feeds TSDB ingestion and metrics).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WindowReport {
    pub arrivals: u64,
    pub served: u64,
    /// Requests dropped because every bounded queue (or the zero-replica
    /// backlog) was full. Counted into `failed_requests`, never silent.
    pub shed: u64,
    /// p95 over this window's completions (`None` when nothing finished).
    pub p95: Option<f64>,
    /// Queued work left at window end (replica queues + backlog).
    pub queue_depth: u64,
}

/// Advance one server's request plane across the window `[from, to)` with
/// `arrivals` new requests. Mutates queues, counters, and histograms;
/// returns the window report.
pub fn step_window(s: &mut ServerState, arrivals: u64, from: Time, to: Time) -> WindowReport {
    let dt = (to - from).max(0.0);
    s.total_requests += arrivals;
    s.window.reset();

    let mu = s.spec.service_rate(); // per-replica requests/second
    let ready: Vec<u32> = s
        .replicas
        .values()
        .filter(|r| r.phase == ReplicaPhase::Ready)
        .map(|r| r.index)
        .collect();

    let mut report = WindowReport { arrivals, ..Default::default() };

    if ready.is_empty() {
        // Nothing can serve: buffer into the bounded backlog (scale-from-
        // zero holds requests for the cold-start duration), shed overflow.
        if arrivals > 0 && s.backlog_since.is_none() {
            s.backlog_since = Some(from);
        }
        s.backlog += arrivals;
        let cap = s.spec.queue_depth as u64 * s.spec.max_replicas.max(1) as u64;
        if s.backlog > cap {
            let shed = s.backlog - cap;
            s.backlog = cap;
            s.failed_requests += shed;
            report.shed = shed;
            s.push_log(to, format!("shed {shed} backlog-full cap={cap}"));
        }
        if arrivals > 0 || s.backlog > 0 {
            s.last_active = to;
        }
        report.queue_depth = s.queued();
        return report;
    }

    // Requests that waited in the backlog carry the cold-start penalty on
    // top of normal queueing when they finally reach a replica.
    let backlog = s.backlog;
    let backlog_wait = match s.backlog_since {
        Some(since) if backlog > 0 => (from - since).max(0.0),
        _ => 0.0,
    };
    s.backlog = 0;
    s.backlog_since = None;

    // Water-fill `pool` over ready replicas, least-outstanding first: raise
    // the common queue level until the pool is exhausted.
    let pool = backlog + arrivals;
    let mut levels: Vec<(u64, u32)> =
        ready.iter().map(|i| (s.replicas[i].outstanding, *i)).collect();
    levels.sort(); // (outstanding asc, index asc) — deterministic
    let mut assigned: Vec<u64> = vec![0; levels.len()];
    let mut remaining = pool;
    let mut k = 0;
    while remaining > 0 {
        // Raise replicas [0..=k] up to the next level (or spread the rest).
        let lift_to = if k + 1 < levels.len() { levels[k + 1].0 } else { u64::MAX };
        let here = levels[k].0;
        let span = (k + 1) as u64;
        let room = (lift_to - here).saturating_mul(span).min(remaining);
        let per = room / span;
        let extra = room % span;
        for (j, a) in assigned.iter_mut().take(k + 1).enumerate() {
            *a += per + if (j as u64) < extra { 1 } else { 0 };
        }
        remaining -= room;
        if k + 1 < levels.len() {
            k += 1;
        }
    }

    // Serve each replica against its batch capacity, bound the queue, and
    // recover latency for this window's completions.
    let per_replica_rate = if dt > 0.0 { pool as f64 / dt / ready.len() as f64 } else { 0.0 };
    let fill_wait = if per_replica_rate > 0.0 {
        // Expected wait for a batch to fill at the offered rate, capped by
        // the flush window: the batching latency knob.
        s.spec.batch_window.min((s.spec.max_batch.saturating_sub(1)) as f64 / (2.0 * per_replica_rate))
    } else {
        0.0
    };
    let base_latency = s.spec.service_time + fill_wait;

    let mut shed_total = 0u64;
    for (slot, (_, idx)) in levels.iter().enumerate() {
        let r = s.replicas.get_mut(idx).expect("ready replica exists");
        let q_before = r.outstanding + assigned[slot];
        // `max(0.0)` also launders a NaN `dt * mu` (0 × ∞) into "no
        // capacity this window" — f64::max returns the non-NaN operand —
        // instead of letting it leak through the carry as a fabricated
        // batch.
        let cap = (r.cap_carry + dt * mu).max(0.0);
        // An unbounded rate serves the whole queue; a finite `cap`
        // saturates the u64 cast, so `served` never exceeds `q_before`
        // either way and served + shed + queued conserves requests exactly.
        let served =
            if cap.is_finite() { q_before.min(cap.floor() as u64) } else { q_before };
        // Carry at most one batch of unused capacity into the next window —
        // never a negative or non-finite amount.
        r.cap_carry = if cap.is_finite() {
            (cap - served as f64).clamp(0.0, s.spec.max_batch as f64)
        } else {
            s.spec.max_batch as f64
        };
        let mut q_after = q_before - served;
        if q_after > s.spec.queue_depth as u64 {
            let shed = q_after - s.spec.queue_depth as u64;
            q_after = s.spec.queue_depth as u64;
            shed_total += shed;
        }
        r.outstanding = q_after;

        if served > 0 {
            // Head-of-window completions waited behind the pre-existing
            // queue; tail completions behind what remains. Split evenly.
            let wait_head = r.outstanding_wait(q_before.saturating_sub(served), mu);
            let wait_tail = r.outstanding_wait(q_after, mu);
            let head = served / 2;
            let tail = served - head;
            s.window.record_n(base_latency + wait_head + backlog_wait, head);
            s.window.record_n(base_latency + wait_tail, tail);
        }
        report.served += served;
    }
    s.latency.merge(&s.window);
    s.completed_requests += report.served;
    if shed_total > 0 {
        s.failed_requests += shed_total;
        report.shed = shed_total;
        s.push_log(to, format!("shed {shed_total} queue-full depth={}", s.spec.queue_depth));
    }
    if arrivals > 0 || s.queued() > 0 {
        s.last_active = to;
    }
    report.p95 = s.window.percentile_checked(95.0);
    if let Some(p) = report.p95 {
        s.last_p95 = p;
    }
    report.queue_depth = s.queued();
    report
}

impl super::Replica {
    /// Expected queueing delay for a request behind `depth` others on a
    /// replica draining at `mu` requests/second.
    fn outstanding_wait(&self, depth: u64, mu: f64) -> f64 {
        if mu > 0.0 { depth as f64 / mu } else { 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::spec;
    use super::super::{Replica, ReplicaPhase, ServerState};
    use super::*;

    fn ready_replica(index: u32) -> Replica {
        Replica {
            index,
            workload: format!("wl-m-r{index}"),
            pod: format!("m-r{index}-i0"),
            phase: ReplicaPhase::Ready,
            incarnation: 0,
            ready_at: Some(0.0),
            outstanding: 0,
            cap_carry: 0.0,
        }
    }

    fn server(n_ready: u32) -> ServerState {
        let mut s = ServerState::new(spec("m"), 0.0);
        for i in 0..n_ready {
            s.replicas.insert(i, ready_replica(i));
        }
        s.desired = n_ready;
        s
    }

    #[test]
    fn underload_serves_everything_within_slo() {
        // 2 replicas at 100 req/s each, offered 50 req/s.
        let mut s = server(2);
        let mut served = 0;
        for w in 0..30 {
            let r = step_window(&mut s, 500, w as f64 * 10.0, (w + 1) as f64 * 10.0);
            served += r.served;
            assert_eq!(r.shed, 0);
        }
        assert_eq!(s.total_requests, 15_000);
        assert_eq!(served + s.queued(), 15_000);
        assert!(s.last_p95 <= s.spec.latency_slo, "p95={}", s.last_p95);
        // accounting invariant: nothing silently dropped
        assert_eq!(s.completed_requests + s.failed_requests + s.queued(), s.total_requests);
    }

    #[test]
    fn overload_sheds_and_counts() {
        // 1 replica at 100 req/s offered 1000 req/s: queues bound at
        // queue_depth, the rest is counted as failed.
        let mut s = server(1);
        for w in 0..10 {
            step_window(&mut s, 10_000, w as f64 * 10.0, (w + 1) as f64 * 10.0);
        }
        assert!(s.failed_requests > 0);
        assert!(s.replicas[&0].outstanding <= s.spec.queue_depth as u64);
        assert_eq!(s.completed_requests + s.failed_requests + s.queued(), s.total_requests);
        assert!(s.trace().contains("shed"));
    }

    #[test]
    fn least_outstanding_evens_out_queues() {
        let mut s = server(3);
        s.replicas.get_mut(&0).unwrap().outstanding = 90;
        // 60 arrivals with dt=0 (no service): all go to the emptier two.
        step_window(&mut s, 60, 0.0, 0.0);
        assert_eq!(s.replicas[&0].outstanding, 90);
        assert_eq!(s.replicas[&1].outstanding, 30);
        assert_eq!(s.replicas[&2].outstanding, 30);
    }

    #[test]
    fn zero_replicas_buffers_then_sheds_at_bound() {
        let mut s = server(0);
        s.spec.max_replicas = 2;
        s.spec.queue_depth = 100;
        let r = step_window(&mut s, 150, 0.0, 10.0);
        assert_eq!(r.shed, 0);
        assert_eq!(s.backlog, 150);
        assert_eq!(s.backlog_since, Some(0.0));
        let r = step_window(&mut s, 150, 10.0, 20.0);
        assert_eq!(r.shed, 100); // bound = 100 * 2
        assert_eq!(s.backlog, 200);
        assert_eq!(s.completed_requests + s.failed_requests + s.queued(), s.total_requests);
    }

    #[test]
    fn backlog_drains_with_cold_start_penalty_when_replica_appears() {
        let mut s = server(0);
        step_window(&mut s, 100, 0.0, 10.0); // buffered at t=0
        s.replicas.insert(0, ready_replica(0));
        let r = step_window(&mut s, 0, 60.0, 70.0);
        assert!(r.served > 0);
        assert_eq!(s.backlog, 0);
        // Head-of-line requests waited ≥ 60s in the backlog.
        assert!(s.window.percentile(95.0) >= 10.0, "p95={}", s.window.percentile(95.0));
    }

    #[test]
    fn batching_window_trades_latency() {
        // Same offered load, bigger batch window ⇒ higher recovered latency
        // (requests wait for batches to fill).
        let run = |batch_window: f64| {
            let mut s = server(2);
            s.spec.batch_window = batch_window;
            for w in 0..20 {
                step_window(&mut s, 100, w as f64 * 10.0, (w + 1) as f64 * 10.0);
            }
            s.latency.mean()
        };
        assert!(run(0.5) > run(0.0));
    }

    #[test]
    fn cap_carry_accumulates_fractionally_and_clamps_at_one_batch() {
        // mu = max_batch / service_time = 8 / 16 = 0.5 req/s: every value
        // in play (0.5, 1.0, the window bounds) is binary-exact, so the
        // pinned pattern is arithmetic, not luck.
        let mut s = server(1);
        s.spec.service_time = 16.0;
        // Seed 10 queued requests through a zero-width window: dt == 0
        // grants no capacity, nothing is served, the queue just fills.
        let r = step_window(&mut s, 10, 0.0, 0.0);
        assert_eq!((r.served, r.queue_depth), (0, 10));
        // 1 s windows grant 0.5 requests each: the fractional carry
        // crosses 1.0 every other window, so service alternates 0, 1, …
        let served: Vec<u64> =
            (0..6).map(|w| step_window(&mut s, 0, w as f64, (w + 1) as f64).served).collect();
        assert_eq!(served, vec![0, 1, 0, 1, 0, 1]);
        // An idle stretch banks at most one batch of capacity …
        for w in 0..50 {
            step_window(&mut s, 0, 100.0 + w as f64, 101.0 + w as f64);
        }
        assert_eq!(s.replicas[&0].outstanding, 0);
        assert_eq!(s.replicas[&0].cap_carry, s.spec.max_batch as f64);
        // … so a burst into a zero-width window serves exactly one batch
        // from the banked carry and leaves no residual capacity behind.
        let r = step_window(&mut s, 20, 200.0, 200.0);
        assert_eq!(r.served, 8);
        assert_eq!(s.replicas[&0].cap_carry, 0.0);
        assert_eq!(s.replicas[&0].outstanding, 12);
        let r = step_window(&mut s, 0, 200.0, 200.0);
        assert_eq!(r.served, 0);
        // nothing fabricated, nothing lost
        assert_eq!(s.completed_requests + s.failed_requests + s.queued(), s.total_requests);
    }

    #[test]
    fn non_finite_capacity_serves_the_queue_and_resets_the_carry() {
        // A poisoned (infinite) carry must not wedge the accounting: the
        // queue drains, the carry comes back finite, and the conservation
        // invariant holds.
        let mut s = server(1);
        s.replicas.get_mut(&0).unwrap().cap_carry = f64::INFINITY;
        let r = step_window(&mut s, 5, 0.0, 0.0);
        assert_eq!(r.served, 5);
        assert_eq!(s.replicas[&0].cap_carry, s.spec.max_batch as f64);
        assert_eq!(s.completed_requests + s.failed_requests + s.queued(), s.total_requests);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = server(2);
        let mut b = server(2);
        for w in 0..50 {
            let (f, t) = (w as f64 * 10.0, (w + 1) as f64 * 10.0);
            assert_eq!(step_window(&mut a, 777, f, t), step_window(&mut b, 777, f, t));
        }
        assert_eq!(a.trace(), b.trace());
    }
}
