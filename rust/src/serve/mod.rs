//! Inference serving: always-on model endpoints on shared MIG GPUs.
//!
//! The serving subsystem realizes each [`InferenceServer`] resource
//! (`crate::api::resources::InferenceServerResource`) as a fleet of
//! replica pods admitted through the same admission → Kueue → scheduler
//! path every other workload class uses, fronted by a
//! least-outstanding-requests load balancer ([`balancer`]) with bounded
//! per-replica queues and request batching, and autoscaled by a
//! latency/queue-depth policy ([`autoscaler`]) that reads its signals from
//! the monitoring TSDB — the SuperSONIC design point: serving shares the
//! accelerators with interactive and batch work instead of owning them.
//!
//! The request plane is *aggregate and deterministic*: the open-loop
//! traffic generator ([`crate::sim::traffic`]) yields arrival counts per
//! reconciliation tick, the balancer water-fills them over ready replicas
//! and serves them against fluid batch capacity, and latencies are
//! recovered analytically (queue wait + batch fill wait + service time)
//! into log-bucketed histograms. No RNG is consumed downstream of the
//! generator, so golden-trace determinism survives serving at
//! millions-of-requests scale.
//!
//! Module map:
//! * [`balancer`] — per-tick request distribution, bounded queues,
//!   batching, latency recovery, shed accounting (no request is silently
//!   dropped: overflow and replica loss are counted as failed);
//! * [`autoscaler`] — desired-replica policy: rate-based sizing with a
//!   target utilization, queue-drain pressure against the SLO budget,
//!   reactive scale-up on p95 breach, scale-to-zero after an idle grace.
//!
//! The controller driving these against the platform lives in
//! [`crate::platform::reconcile::serve`]; replica pod/workload plumbing in
//! `crate::platform::serving`.

pub mod autoscaler;
pub mod balancer;

use std::collections::BTreeMap;

use crate::cluster::resources::ResourceVec;
use crate::sim::clock::Time;
use crate::util::stats::Histogram;

pub use autoscaler::{desired_replicas, ScalePolicy, ScaleSignals};
pub use balancer::{step_window, WindowReport};

/// The serving-side mirror of an `InferenceServer` spec (post-admission:
/// every knob defaulted and validated).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingSpec {
    pub name: String,
    pub user: String,
    pub project: String,
    pub model: String,
    /// Per-replica resource request (MIG-slice-sized).
    pub requests: ResourceVec,
    pub min_replicas: u32,
    pub max_replicas: u32,
    /// p95 latency objective (seconds).
    pub latency_slo: f64,
    /// Max requests coalesced into one GPU batch.
    pub max_batch: u32,
    /// Seconds a replica waits to fill a partial batch.
    pub batch_window: f64,
    /// Seconds one batch occupies a replica.
    pub service_time: f64,
    /// Bounded per-replica queue length.
    pub queue_depth: u32,
    /// Kueue LocalQueue replica workloads are submitted to.
    pub queue: String,
}

impl ServingSpec {
    /// Saturated per-replica throughput (requests/second).
    pub fn service_rate(&self) -> f64 {
        self.max_batch as f64 / self.service_time.max(1e-9)
    }
}

/// Replica lifecycle phase, as the serving controller tracks it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaPhase {
    /// Workload submitted to Kueue, awaiting (re)admission.
    Queued,
    /// Pod created; container starting and/or model loading (cold start).
    Starting,
    /// Serving traffic.
    Ready,
}

/// One serving replica: a Kueue workload realizing a pod.
#[derive(Debug, Clone)]
pub struct Replica {
    pub index: u32,
    pub workload: String,
    pub pod: String,
    pub phase: ReplicaPhase,
    /// Pod incarnation (a replacement pod after preemption gets a new one).
    pub incarnation: u32,
    /// When the replica finishes its model-load cold start (set when the
    /// pod reaches Running).
    pub ready_at: Option<Time>,
    /// Requests currently queued on this replica.
    pub outstanding: u64,
    /// Fractional batch capacity carried between windows (fluid service).
    pub cap_carry: f64,
}

/// Live state of one inference server: spec, replica fleet, balancer
/// queues, latency histograms, counters, and the append-only transition
/// log golden traces diff.
#[derive(Debug)]
pub struct ServerState {
    pub spec: ServingSpec,
    pub replicas: BTreeMap<u32, Replica>,
    pub next_index: u32,
    /// Autoscaler target (replicas converge toward this).
    pub desired: u32,
    /// Requests buffered at the balancer while no replica is ready
    /// (scale-from-zero, all-replica loss). Bounded; overflow is shed.
    pub backlog: u64,
    /// When the oldest backlogged request arrived (cold-start latency).
    pub backlog_since: Option<Time>,
    /// Cumulative request latency.
    pub latency: Histogram,
    /// Current-window latency (reset each tick after the p95 is scraped).
    pub window: Histogram,
    pub total_requests: u64,
    pub completed_requests: u64,
    /// Shed (queue full) + lost to replica failure. Never silent.
    pub failed_requests: u64,
    /// Last p95 scraped from a non-empty window (status surface).
    pub last_p95: f64,
    /// Last time the server saw arrivals or held queued work.
    pub last_active: Time,
    /// Next autoscale evaluation time.
    pub next_scale_at: Time,
    /// Transition log: `(time, line)` — replica lifecycle, scale
    /// decisions, shed windows. Rendered by `trace()`.
    pub log: Vec<(Time, String)>,
}

impl ServerState {
    pub fn new(spec: ServingSpec, now: Time) -> ServerState {
        ServerState {
            spec,
            replicas: BTreeMap::new(),
            next_index: 0,
            desired: 0,
            backlog: 0,
            backlog_since: None,
            latency: Histogram::latency(),
            window: Histogram::latency(),
            total_requests: 0,
            completed_requests: 0,
            failed_requests: 0,
            last_p95: 0.0,
            last_active: now,
            next_scale_at: now,
            log: Vec::new(),
        }
    }

    /// Replicas currently serving traffic.
    pub fn ready_count(&self) -> u32 {
        self.replicas.values().filter(|r| r.phase == ReplicaPhase::Ready).count() as u32
    }

    /// Total queued work (replica queues + balancer backlog).
    pub fn queued(&self) -> u64 {
        self.backlog + self.replicas.values().map(|r| r.outstanding).sum::<u64>()
    }

    /// Status string for the API projection.
    pub fn state_str(&self) -> &'static str {
        let ready = self.ready_count();
        if self.desired == 0 && self.replicas.is_empty() {
            "Idle"
        } else if ready == self.desired && ready == self.replicas.len() as u32 {
            "Serving"
        } else {
            "Scaling"
        }
    }

    pub fn push_log(&mut self, at: Time, line: String) {
        self.log.push((at, line));
    }

    /// The transition log rendered one line per event (golden traces).
    pub fn trace(&self) -> String {
        let mut s = String::new();
        for (at, line) in &self.log {
            s.push_str(&format!("{:10.3} SERVING {} {}\n", at, self.spec.name, line));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn spec(name: &str) -> ServingSpec {
        ServingSpec {
            name: name.into(),
            user: "user001".into(),
            project: "project01".into(),
            model: "deepmet".into(),
            requests: ResourceVec::cpu_millis(2000).with("nvidia.com/mig-1g.5gb", 1),
            min_replicas: 0,
            max_replicas: 4,
            latency_slo: 0.5,
            max_batch: 8,
            batch_window: 0.02,
            service_time: 0.08,
            queue_depth: 100,
            queue: "serving".into(),
        }
    }

    #[test]
    fn state_strings_follow_fleet() {
        let mut s = ServerState::new(spec("m"), 0.0);
        assert_eq!(s.state_str(), "Idle");
        s.desired = 1;
        s.replicas.insert(
            0,
            Replica {
                index: 0,
                workload: "wl-m-r0".into(),
                pod: "m-r0-i0".into(),
                phase: ReplicaPhase::Starting,
                incarnation: 0,
                ready_at: None,
                outstanding: 0,
                cap_carry: 0.0,
            },
        );
        assert_eq!(s.state_str(), "Scaling");
        s.replicas.get_mut(&0).unwrap().phase = ReplicaPhase::Ready;
        assert_eq!(s.state_str(), "Serving");
    }

    #[test]
    fn trace_lines_are_stable() {
        let mut s = ServerState::new(spec("m"), 0.0);
        s.push_log(12.5, "scale 0 -> 2 reason=burst".into());
        assert_eq!(s.trace(), "    12.500 SERVING m scale 0 -> 2 reason=burst\n");
    }
}
