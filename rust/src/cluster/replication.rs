//! Hot-standby replication for the coordinator control plane.
//!
//! The leader ships WAL frames (see [`crate::cluster::wal`]) to a
//! [`Replica`] over a simulated channel. The replica verifies each
//! frame's CRC, enforces monotonic writer epochs (fencing deposed
//! leaders), and re-frames accepted payloads into its own local log so
//! promotion can replay them with the exact machinery `crash_and_restore`
//! uses. Periodic snapshot transfer bounds catch-up: installing a
//! snapshot clears the replica log and advances the ship cursor, so the
//! replica only ever holds `snapshot + tail`.
//!
//! Leader election is lease-based and deterministic: the live leader
//! renews its [`Lease`] at tick boundaries; when the platform observes
//! the lease expired (leader killed or isolated by chaos), the standby
//! promotes under a bumped epoch. Epoch fencing then rejects any write
//! the deposed leader attempts after resurrection — both at the shipping
//! channel (`min_epoch` here) and at the store/Kueue mutation guards.

use std::fmt;

use crate::cluster::wal::{Frame, Wal, WalReplay};
use crate::sim::clock::Time;

/// Why the standby refused a shipped frame.
#[derive(Debug, Clone, PartialEq)]
pub enum ShipError {
    /// The frame's carried CRC does not match its contents: corruption
    /// in flight (or on the leader's disk). The channel must stop — the
    /// frame cannot be trusted and skipping it would leave a gap.
    Corrupt { index: u64 },
    /// The frame's writer epoch predates the fence: a deposed leader is
    /// still writing. The write is dropped and counted, never applied.
    Fenced { frame_epoch: u64, min_epoch: u64 },
    /// The frame is not the next one expected. Shipping is strictly
    /// sequential; a gap means the channel and replica desynchronized.
    Gap { expected: u64, got: u64 },
}

impl fmt::Display for ShipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShipError::Corrupt { index } => write!(f, "frame {index}: CRC mismatch on ingest"),
            ShipError::Fenced { frame_epoch, min_epoch } => {
                write!(f, "frame epoch {frame_epoch} fenced (min epoch {min_epoch})")
            }
            ShipError::Gap { expected, got } => {
                write!(f, "shipping gap: expected frame {expected}, got {got}")
            }
        }
    }
}

/// Ingest/shipping counters, surfaced through `PlatformMetrics`.
#[derive(Debug, Default, Clone)]
pub struct ReplicationStats {
    /// Frames accepted into the replica log since creation.
    pub frames_ingested: u64,
    /// Snapshot transfers installed (each clears the replica log).
    pub snapshots_installed: u64,
    /// Stale-epoch frames rejected by the channel fence.
    pub fenced_frames: u64,
    /// Frames rejected for CRC mismatch.
    pub corrupt_frames: u64,
}

/// The hot standby: latest transferred snapshot plus the shipped log
/// tail since that snapshot. Promotion decodes the snapshot and replays
/// the tail — the same restore path as local crash recovery.
#[derive(Debug)]
pub struct Replica {
    snapshot: Vec<u8>,
    snapshot_at: Time,
    /// Shipped frames re-framed locally, preserving each original
    /// writer epoch, so promotion reuses `Wal::replay_report`.
    log: Wal,
    /// Next absolute leader-log frame index this replica expects.
    next_frame: u64,
    /// Frames below this epoch are from deposed leaders — fenced.
    min_epoch: u64,
    pub stats: ReplicationStats,
}

impl Replica {
    /// Seed a standby from the leader's current snapshot bytes and ship
    /// cursor position.
    pub fn new(snapshot: Vec<u8>, snapshot_at: Time, min_epoch: u64, next_frame: u64) -> Self {
        Replica {
            snapshot,
            snapshot_at,
            log: Wal::new(),
            next_frame,
            min_epoch,
            stats: ReplicationStats::default(),
        }
    }

    /// Accept one shipped frame. Order of checks matters: CRC first
    /// (nothing in a corrupt frame can be trusted), then the epoch
    /// fence, then sequencing.
    pub fn ingest(&mut self, f: &Frame) -> Result<(), ShipError> {
        if !f.verify() {
            self.stats.corrupt_frames += 1;
            return Err(ShipError::Corrupt { index: f.index });
        }
        if f.epoch < self.min_epoch {
            self.stats.fenced_frames += 1;
            return Err(ShipError::Fenced { frame_epoch: f.epoch, min_epoch: self.min_epoch });
        }
        if f.index != self.next_frame {
            return Err(ShipError::Gap { expected: self.next_frame, got: f.index });
        }
        self.log.append_frame(f.epoch, &f.payload);
        self.next_frame = f.index + 1;
        self.stats.frames_ingested += 1;
        Ok(())
    }

    /// Install a fresh snapshot transfer: replaces the held snapshot,
    /// drops the now-compacted log tail, and advances the ship cursor to
    /// the leader's post-compaction base frame.
    pub fn install_snapshot(&mut self, bytes: Vec<u8>, at: Time, next_frame: u64) {
        self.snapshot = bytes;
        self.snapshot_at = at;
        self.log.clear();
        self.next_frame = next_frame;
        self.stats.snapshots_installed += 1;
    }

    /// Raise the channel fence (promotion bumps this to the new epoch).
    pub fn set_min_epoch(&mut self, epoch: u64) {
        self.min_epoch = epoch;
    }

    pub fn min_epoch(&self) -> u64 {
        self.min_epoch
    }

    /// Next absolute leader-log frame index expected on the channel.
    pub fn next_frame(&self) -> u64 {
        self.next_frame
    }

    /// Snapshot bytes as last transferred.
    pub fn snapshot(&self) -> &[u8] {
        &self.snapshot
    }

    pub fn snapshot_at(&self) -> Time {
        self.snapshot_at
    }

    /// Frames held in the local log since the last snapshot install —
    /// exactly what promotion will replay.
    pub fn frames_since_snapshot(&self) -> u64 {
        self.log.next_frame() - self.log.base_frame()
    }

    /// Decode the shipped tail for promotion replay. Damage surfaces as
    /// a typed truncation, never a panic — promotion aborts cleanly.
    pub fn replay(&self) -> WalReplay {
        self.log.replay_report()
    }

    /// Bytes held in the replica's local log (the shipped tail).
    pub fn log_len_bytes(&self) -> usize {
        self.log.len_bytes()
    }

    /// Test hook: flip one byte of the replica's local log to model
    /// standby-side storage corruption.
    pub fn corrupt_log_byte(&mut self, at: usize) {
        self.log.corrupt_byte(at);
    }

    /// Test hook: cut the held snapshot short to model a damaged
    /// transfer (truncation always fails decode deterministically; a
    /// flipped byte might decode to plausible garbage).
    pub fn truncate_snapshot(&mut self, len: usize) {
        self.snapshot.truncate(len);
    }
}

/// The leader lease. Renewal is deterministic — the live, un-isolated
/// leader renews at every tick boundary; expiry is the standby's signal
/// to promote.
#[derive(Debug, Clone)]
pub struct Lease {
    /// Epoch of the current holder (informational; fencing uses the
    /// store/channel guards, not the lease).
    pub holder_epoch: u64,
    pub duration: Time,
    pub expires_at: Time,
}

impl Lease {
    pub fn new(holder_epoch: u64, duration: Time, now: Time) -> Self {
        Lease { holder_epoch, duration, expires_at: now + duration }
    }

    pub fn renew(&mut self, now: Time) {
        self.expires_at = now + self.duration;
    }

    pub fn expired(&self, now: Time) -> bool {
        now >= self.expires_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::wal::{StoreOp, WalRecord};

    fn sample_frames(epoch: u64, n: usize) -> (Wal, Vec<Frame>) {
        let mut w = Wal::new();
        w.set_epoch(epoch);
        for i in 0..n {
            w.append(&WalRecord::Control(format!("op-{i}").into_bytes()));
        }
        let frames = w.frames(0, w.next_frame()).unwrap();
        (w, frames)
    }

    #[test]
    fn ingest_applies_in_order_and_replays() {
        let (_, frames) = sample_frames(1, 3);
        let mut r = Replica::new(Vec::new(), 0.0, 1, 0);
        for f in &frames {
            r.ingest(f).unwrap();
        }
        assert_eq!(r.stats.frames_ingested, 3);
        assert_eq!(r.next_frame(), 3);
        assert_eq!(r.frames_since_snapshot(), 3);
        let rep = r.replay();
        assert!(rep.truncation.is_none());
        assert_eq!(rep.records.len(), 3);
        assert!(rep.records.iter().all(|(e, _)| *e == 1));
    }

    #[test]
    fn stale_epoch_frames_are_fenced_not_applied() {
        let (_, frames) = sample_frames(1, 2);
        let mut r = Replica::new(Vec::new(), 0.0, 2, 0);
        for f in &frames {
            assert!(matches!(
                r.ingest(f),
                Err(ShipError::Fenced { frame_epoch: 1, min_epoch: 2 })
            ));
        }
        assert_eq!(r.stats.fenced_frames, 2);
        assert_eq!(r.frames_since_snapshot(), 0, "fenced frames never enter the log");
        // the cursor does not advance either: a fenced write is dropped,
        // not acknowledged
        assert_eq!(r.next_frame(), 0);
    }

    #[test]
    fn corrupt_frame_is_rejected_before_any_other_check() {
        let (_, frames) = sample_frames(1, 1);
        let mut bad = frames[0].clone();
        bad.payload[0] ^= 0xFF;
        let mut r = Replica::new(Vec::new(), 0.0, 1, 0);
        assert_eq!(r.ingest(&bad), Err(ShipError::Corrupt { index: 0 }));
        assert_eq!(r.stats.corrupt_frames, 1);
        assert_eq!(r.frames_since_snapshot(), 0);
    }

    #[test]
    fn out_of_order_frame_is_a_gap_error() {
        let (_, frames) = sample_frames(1, 2);
        let mut r = Replica::new(Vec::new(), 0.0, 1, 0);
        assert_eq!(
            r.ingest(&frames[1]),
            Err(ShipError::Gap { expected: 0, got: 1 })
        );
        r.ingest(&frames[0]).unwrap();
        r.ingest(&frames[1]).unwrap();
        assert_eq!(r.next_frame(), 2);
    }

    #[test]
    fn snapshot_install_clears_tail_and_advances_cursor() {
        let (_, frames) = sample_frames(1, 3);
        let mut r = Replica::new(vec![1, 2, 3], 0.0, 1, 0);
        for f in &frames {
            r.ingest(f).unwrap();
        }
        r.install_snapshot(vec![9, 9], 120.0, 3);
        assert_eq!(r.snapshot(), &[9, 9]);
        assert_eq!(r.snapshot_at(), 120.0);
        assert_eq!(r.frames_since_snapshot(), 0);
        assert_eq!(r.next_frame(), 3);
        assert_eq!(r.stats.snapshots_installed, 1);
        // shipping resumes seamlessly from the post-compaction cursor
        let mut w = Wal::new();
        w.set_epoch(1);
        for _ in 0..4 {
            w.append(&WalRecord::Store(StoreOp::GcFinished { before: 0.0 }));
        }
        let tail = w.frames(3, 4).unwrap();
        r.ingest(&tail[0]).unwrap();
        assert_eq!(r.frames_since_snapshot(), 1);
    }

    #[test]
    fn corrupted_replica_log_surfaces_typed_truncation() {
        let (_, frames) = sample_frames(1, 3);
        let mut r = Replica::new(Vec::new(), 0.0, 1, 0);
        for f in &frames {
            r.ingest(f).unwrap();
        }
        r.corrupt_log_byte(20);
        let rep = r.replay();
        assert!(rep.truncation.is_some(), "damage must be reported, not ignored");
        assert!(rep.records.len() < 3);
    }

    #[test]
    fn lease_renewal_and_expiry_are_deterministic() {
        let mut l = Lease::new(1, 30.0, 100.0);
        assert!(!l.expired(129.9));
        assert!(l.expired(130.0), "expiry boundary is inclusive");
        l.renew(125.0);
        assert!(!l.expired(130.0));
        assert!(l.expired(155.0));
    }
}
