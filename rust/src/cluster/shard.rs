//! Shard-layer primitives for the multi-coordinator control plane: the
//! zone → shard router, the two-phase (reserve/bind) cross-shard capacity
//! ledger, and the typed rebalance plan the federation's rebalance
//! reconciler executes.
//!
//! A *shard* is a full coordinator (its own [`crate::cluster::store::ClusterStore`],
//! WAL, ring logs, free-capacity indexes, Kueue and reconciler runtime) —
//! see [`crate::platform::federation`] for the layer that composes shards.
//! This module holds only the shard-agnostic data structures, so they can
//! be unit-tested without bootstrapping a platform.
//!
//! ## The two-phase protocol
//!
//! Cross-shard scheduling never mutates a remote shard directly. Phase 1
//! (**reserve**) claims capacity against the target shard's advertised
//! headroom *minus every outstanding reservation* in the ledger, so
//! concurrent reservations can never oversubscribe a shard (no
//! double-bind). Phase 2 (**bind**) consumes the reservation exactly once
//! by submitting through the shard's normal admission path. A reservation
//! that is never bound — the requester crashed, the target shard was lost —
//! is released by its deadline ([`ReservationLedger::expire`]), so no
//! capacity leaks and no pair of shards can deadlock waiting on each
//! other's claims. The conservation law tests assert:
//!
//! ```text
//! created == bound + released + expired + active
//! ```

use std::collections::BTreeMap;

use crate::cluster::resources::ResourceVec;
use crate::sim::clock::Time;

/// FNV-1a — stable across platforms/runs, so routing is deterministic and
/// reproducible in golden traces (no `DefaultHasher` seed dependence).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Maps ownership keys (zones — node names or `aiinfn/zone` label values —
/// and users) onto shard indexes. Explicit assignments (made at bootstrap
/// and updated by rebalancing) win; unknown keys fall back to a stable
/// hash, so routing is total and deterministic.
#[derive(Debug, Clone)]
pub struct ShardRouter {
    shard_count: usize,
    assignments: BTreeMap<String, usize>,
}

impl ShardRouter {
    pub fn new(shard_count: usize) -> ShardRouter {
        ShardRouter { shard_count: shard_count.max(1), assignments: BTreeMap::new() }
    }

    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// Pin `zone` to `shard` (bootstrap ownership, or a completed
    /// rebalance flipping the owner).
    pub fn assign(&mut self, zone: &str, shard: usize) {
        self.assignments.insert(zone.to_string(), shard % self.shard_count);
    }

    /// The shard owning `zone`: its pinned assignment, else the hash
    /// fallback.
    pub fn route(&self, zone: &str) -> usize {
        match self.assignments.get(zone) {
            Some(&s) => s,
            None => (fnv1a(zone) % self.shard_count as u64) as usize,
        }
    }

    /// The home shard for a user's submissions (pure hash: users are not
    /// pinned, so adding shards re-spreads them deterministically).
    pub fn route_user(&self, user: &str) -> usize {
        (fnv1a(user) % self.shard_count as u64) as usize
    }

    /// Zones explicitly assigned to `shard`, in sorted order.
    pub fn zones_of(&self, shard: usize) -> Vec<&str> {
        self.assignments
            .iter()
            .filter(|(_, &s)| s == shard)
            .map(|(z, _)| z.as_str())
            .collect()
    }
}

/// One outstanding phase-1 capacity claim against a shard.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    pub id: u64,
    /// Target shard whose headroom is claimed.
    pub shard: usize,
    pub requests: ResourceVec,
    pub created: Time,
    /// Deadline after which the claim is released unbound.
    pub expires: Time,
}

/// Conservation counters over the ledger's lifetime.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LedgerStats {
    /// Phase-1 claims granted.
    pub created: u64,
    /// Claims consumed by a phase-2 bind (each exactly once).
    pub bound: u64,
    /// Claims explicitly released by the requester.
    pub released: u64,
    /// Claims released by their deadline (requester never bound).
    pub expired: u64,
    /// Phase-1 attempts rejected for insufficient headroom.
    pub rejected: u64,
}

/// The federation-wide reservation ledger (phase-1 state of the two-phase
/// protocol). Single-writer by construction — the federation layer owns
/// it — so admission control is a plain headroom comparison, not a
/// consensus problem.
#[derive(Debug, Default)]
pub struct ReservationLedger {
    next_id: u64,
    active: BTreeMap<u64, Reservation>,
    stats: LedgerStats,
}

impl ReservationLedger {
    pub fn new() -> ReservationLedger {
        ReservationLedger::default()
    }

    /// Sum of active claims against `shard` — the part of its advertised
    /// headroom already spoken for.
    pub fn outstanding(&self, shard: usize) -> ResourceVec {
        let mut v = ResourceVec::new();
        for r in self.active.values() {
            if r.shard == shard {
                v.add(&r.requests);
            }
        }
        v
    }

    /// Phase 1: claim `requests` against `headroom` (the shard's free
    /// capacity/quota as advertised *now*). Fails — without side effects
    /// beyond the rejection counter — if the claim plus everything already
    /// outstanding would oversubscribe the shard.
    pub fn reserve(
        &mut self,
        shard: usize,
        requests: &ResourceVec,
        headroom: &ResourceVec,
        now: Time,
        ttl: Time,
    ) -> Option<u64> {
        let mut claimed = self.outstanding(shard);
        claimed.add(requests);
        if !claimed.fits_in(headroom) {
            self.stats.rejected += 1;
            return None;
        }
        self.next_id += 1;
        let id = self.next_id;
        self.active.insert(
            id,
            Reservation {
                id,
                shard,
                requests: requests.clone(),
                created: now,
                expires: now + ttl.max(0.0),
            },
        );
        self.stats.created += 1;
        Some(id)
    }

    /// Phase 2: consume the reservation. Returns `None` if it was already
    /// bound, released, or expired — the caller must treat that as "claim
    /// lost, do not submit", which is what makes double-binding impossible.
    pub fn bind(&mut self, id: u64) -> Option<Reservation> {
        let r = self.active.remove(&id)?;
        self.stats.bound += 1;
        Some(r)
    }

    /// Give a claim back without binding it.
    pub fn release(&mut self, id: u64) -> Option<Reservation> {
        let r = self.active.remove(&id)?;
        self.stats.released += 1;
        Some(r)
    }

    /// Release every claim whose deadline has passed, in id order.
    pub fn expire(&mut self, now: Time) -> Vec<Reservation> {
        let dead: Vec<u64> =
            self.active.values().filter(|r| r.expires <= now).map(|r| r.id).collect();
        let mut out = Vec::with_capacity(dead.len());
        for id in dead {
            if let Some(r) = self.active.remove(&id) {
                self.stats.expired += 1;
                out.push(r);
            }
        }
        out
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn stats(&self) -> LedgerStats {
        self.stats
    }

    /// The conservation law: every claim ever created is accounted for
    /// exactly once. Violations mean a leak or a double-bind.
    pub fn balanced(&self) -> bool {
        self.stats.created
            == self.stats.bound
                + self.stats.released
                + self.stats.expired
                + self.active.len() as u64
    }
}

/// A requested zone migration: move every node of `zone` from shard
/// `from` to shard `to`. Executed as a reconciler by the federation —
/// cordon, drain, snapshot-ship, re-register — see
/// [`crate::platform::federation`].
#[derive(Debug, Clone, PartialEq)]
pub struct RebalancePlan {
    pub zone: String,
    pub from: usize,
    pub to: usize,
}

/// Where an in-flight rebalance is in its state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalancePhase {
    /// Nodes cordoned on the source shard; waiting for live pods to drain.
    Draining,
    /// Drained: nodes snapshot-shipped and re-registered on the target.
    Done,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_is_deterministic_and_total() {
        let mut r = ShardRouter::new(4);
        r.assign("zone-a", 1);
        r.assign("zone-b", 3);
        assert_eq!(r.route("zone-a"), 1);
        assert_eq!(r.route("zone-b"), 3);
        // unknown zones fall back to a stable hash inside range
        let z = r.route("never-assigned");
        assert!(z < 4);
        assert_eq!(z, r.route("never-assigned"));
        assert_eq!(r.route_user("user001"), r.route_user("user001"));
        assert!(r.route_user("user001") < 4);
        // reassignment flips the owner (rebalance)
        r.assign("zone-a", 2);
        assert_eq!(r.route("zone-a"), 2);
        assert_eq!(r.zones_of(2), vec!["zone-a"]);
    }

    #[test]
    fn single_shard_router_routes_everything_to_zero() {
        let r = ShardRouter::new(1);
        assert_eq!(r.route("anything"), 0);
        assert_eq!(r.route_user("user077"), 0);
    }

    #[test]
    fn reserve_respects_headroom_minus_outstanding() {
        let mut l = ReservationLedger::new();
        let headroom = ResourceVec::cpu_millis(10_000);
        let req = ResourceVec::cpu_millis(4_000);
        let a = l.reserve(0, &req, &headroom, 0.0, 60.0).expect("first fits");
        let _b = l.reserve(0, &req, &headroom, 0.0, 60.0).expect("second fits");
        // 8000 outstanding: a third 4000 claim would oversubscribe
        assert!(l.reserve(0, &req, &headroom, 0.0, 60.0).is_none());
        assert_eq!(l.stats().rejected, 1);
        // but another shard's headroom is independent
        assert!(l.reserve(1, &req, &headroom, 0.0, 60.0).is_some());
        // releasing frees the claim for a retry
        l.release(a).unwrap();
        assert!(l.reserve(0, &req, &headroom, 1.0, 60.0).is_some());
        assert!(l.balanced());
    }

    #[test]
    fn bind_consumes_exactly_once() {
        let mut l = ReservationLedger::new();
        let id = l
            .reserve(2, &ResourceVec::cpu_millis(1000), &ResourceVec::cpu_millis(2000), 0.0, 30.0)
            .unwrap();
        assert!(l.bind(id).is_some());
        assert!(l.bind(id).is_none(), "double bind must be refused");
        assert!(l.release(id).is_none());
        assert_eq!(l.stats().bound, 1);
        assert!(l.balanced());
    }

    #[test]
    fn expiry_releases_unbound_claims_by_deadline() {
        let mut l = ReservationLedger::new();
        let h = ResourceVec::cpu_millis(10_000);
        let id1 = l.reserve(0, &ResourceVec::cpu_millis(1000), &h, 0.0, 10.0).unwrap();
        let id2 = l.reserve(0, &ResourceVec::cpu_millis(1000), &h, 0.0, 100.0).unwrap();
        assert!(l.expire(5.0).is_empty());
        let dead = l.expire(10.0);
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].id, id1);
        assert!(l.bind(id1).is_none(), "expired claim must not bind");
        assert!(l.bind(id2).is_some(), "live claim still binds");
        assert!(l.outstanding(0).is_empty());
        assert_eq!(l.stats().expired, 1);
        assert!(l.balanced());
    }
}
