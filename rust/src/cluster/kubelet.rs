//! Kubelet simulator: drives bound pods through their lifecycle on the
//! discrete-event engine.
//!
//! Start latency models container startup (image pull amortized by a node
//! cache, runtime setup); run duration comes from the pod payload via a
//! pluggable [`DurationOracle`] so the same kubelet serves pure simulation
//! (durations from the trace / cost model) and hardware-in-the-loop runs
//! (durations measured around real PJRT execution by the platform facade).

use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

use crate::cluster::pod::{Payload, PodPhase};
use crate::cluster::store::ClusterStore;
use crate::sim::clock::Time;
use crate::sim::engine::Engine;

/// Maps a payload to its active run duration (seconds of sim time).
pub type DurationOracle = Rc<dyn Fn(&Payload) -> Time>;

/// Default oracle: honor explicit durations; sessions run to their idle
/// timeout; compute payloads fall back to a nominal rate (overridden by the
/// platform's cost model in real setups).
pub fn default_oracle() -> DurationOracle {
    Rc::new(|p: &Payload| match p {
        Payload::Sleep { duration } => *duration,
        Payload::Session { idle_after } => *idle_after,
        Payload::MlJob { steps, .. } => *steps as f64 * 0.5,
        Payload::Burn { flops } => flops / 1e12, // 1 TFLOPS nominal
    })
}

/// Shared kubelet state (image cache per node).
pub struct Kubelet {
    store: Rc<RefCell<ClusterStore>>,
    oracle: DurationOracle,
    /// (node, image-ish key) pairs already warm — first pull is slower.
    warm: RefCell<HashSet<(String, String)>>,
    pub cold_start: Time,
    pub warm_start: Time,
}

impl Kubelet {
    pub fn new(store: Rc<RefCell<ClusterStore>>, oracle: DurationOracle) -> Rc<Self> {
        Rc::new(Kubelet {
            store,
            oracle,
            warm: RefCell::new(HashSet::new()),
            cold_start: 30.0, // first image pull on a node
            warm_start: 2.0,  // cached image
        })
    }

    /// Begin lifecycle for a pod that was just bound. Schedules Running and
    /// the terminal transition on the engine.
    pub fn launch(self: &Rc<Self>, eng: &mut Engine, pod_name: &str) {
        let (node, payload, image_key) = {
            let st = self.store.borrow();
            let Some(pod) = st.pod(pod_name) else { return };
            if pod.status.phase != PodPhase::Scheduled {
                return;
            }
            let image = match &pod.spec.payload {
                Payload::MlJob { artifact, .. } => format!("mljob/{artifact}"),
                Payload::Session { .. } => "jupyter/datascience".to_string(),
                _ => "batch/generic".to_string(),
            };
            (pod.status.node.clone().unwrap_or_default(), pod.spec.payload.clone(), image)
        };
        let key = (node, image_key);
        let start_delay = if self.warm.borrow().contains(&key) {
            self.warm_start
        } else {
            self.warm.borrow_mut().insert(key);
            self.cold_start
        };
        let me = self.clone();
        let name = pod_name.to_string();
        eng.after(start_delay, move |e| me.start(e, &name, &payload));
    }

    fn start(self: Rc<Self>, eng: &mut Engine, pod_name: &str, payload: &Payload) {
        {
            let mut st = self.store.borrow_mut();
            // pod may have been evicted while image-pulling
            let live = st.pod(pod_name).map(|p| p.status.phase == PodPhase::Scheduled).unwrap_or(false);
            if !live {
                return;
            }
            let now = eng.now();
            st.mark_running(pod_name, now).ok();
        }
        let dur = (self.oracle)(payload).max(0.0);
        let me = self.clone();
        let name = pod_name.to_string();
        eng.after(dur, move |e| {
            let mut st = me.store.borrow_mut();
            let running = st.pod(&name).map(|p| p.status.phase == PodPhase::Running).unwrap_or(false);
            if running {
                let now = e.now();
                st.finish_pod(&name, PodPhase::Succeeded, now, "completed").ok();
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Node;
    use crate::cluster::pod::PodSpec;
    use crate::cluster::resources::ResourceVec;
    use crate::sim::clock::SimClock;

    fn setup() -> (Engine, Rc<RefCell<ClusterStore>>, Rc<Kubelet>) {
        let clock = SimClock::new();
        let eng = Engine::new(clock);
        let store = Rc::new(RefCell::new(ClusterStore::new()));
        store
            .borrow_mut()
            .add_node(Node::physical("n1", 8, 32 << 30, 1 << 40, vec![]), 0.0);
        let kubelet = Kubelet::new(store.clone(), default_oracle());
        (eng, store, kubelet)
    }

    #[test]
    fn pod_runs_to_completion() {
        let (mut eng, store, kubelet) = setup();
        store.borrow_mut().create_pod(
            PodSpec::new("p1", ResourceVec::cpu_millis(100), Payload::Sleep { duration: 10.0 }),
            0.0,
        );
        store.borrow_mut().bind("p1", "n1", 0.0).unwrap();
        kubelet.launch(&mut eng, "p1");
        eng.run_until(100.0);
        let st = store.borrow();
        let p = st.pod("p1").unwrap();
        assert_eq!(p.status.phase, PodPhase::Succeeded);
        // cold start 30 + duration 10
        assert!((p.status.finished_at.unwrap() - 40.0).abs() < 1e-6);
    }

    #[test]
    fn warm_start_is_faster_second_time() {
        let (mut eng, store, kubelet) = setup();
        for (name, t) in [("a", 0.0), ("b", 0.0)] {
            store.borrow_mut().create_pod(
                PodSpec::new(name, ResourceVec::cpu_millis(100), Payload::Sleep { duration: 1.0 }),
                t,
            );
            store.borrow_mut().bind(name, "n1", t).unwrap();
        }
        kubelet.launch(&mut eng, "a");
        kubelet.launch(&mut eng, "b"); // same image key, same node → warm
        eng.run_until(100.0);
        let st = store.borrow();
        let fa = st.pod("a").unwrap().status.finished_at.unwrap();
        let fb = st.pod("b").unwrap().status.finished_at.unwrap();
        assert!((fa - 31.0).abs() < 1e-6, "{fa}");
        assert!((fb - 3.0).abs() < 1e-6, "warm pod should finish first: {fb}");
    }

    #[test]
    fn evicted_pod_does_not_complete() {
        let (mut eng, store, kubelet) = setup();
        store.borrow_mut().create_pod(
            PodSpec::new("p1", ResourceVec::cpu_millis(100), Payload::Sleep { duration: 50.0 }),
            0.0,
        );
        store.borrow_mut().bind("p1", "n1", 0.0).unwrap();
        kubelet.launch(&mut eng, "p1");
        // evict mid-run at t=35 (after start at 30)
        {
            let store = store.clone();
            eng.at(35.0, move |e| {
                let now = e.now();
                store.borrow_mut().evict_pod("p1", now, false, "test evict").ok();
            });
        }
        eng.run_until(200.0);
        let st = store.borrow();
        assert_eq!(st.pod("p1").unwrap().status.phase, PodPhase::Evicted);
    }
}
