//! Write-ahead log for the control plane (DESIGN: crash tolerance).
//!
//! Every state-mutating transition of the [`ClusterStore`] and the Kueue
//! controller appends one framed [`WalRecord`] here *before* executing, so
//! a coordinator crash can be recovered by replaying the log tail over the
//! last snapshot. The log models durable storage in the simulation: the
//! buffer survives the simulated coordinator kill (the in-memory stand-in
//! for an fsync'd file), while everything else about the coordinator is
//! rebuilt from snapshot + replay.
//!
//! Frame format, per record:
//!
//! ```text
//! [len: u32 LE] [crc: u32 LE] [epoch: u64 LE] [payload: len bytes]
//! ```
//!
//! `epoch` is the writer's leader term (see
//! [`cluster::replication`](crate::cluster::replication)): a hot standby
//! consuming shipped frames rejects any frame whose epoch predates the
//! current term, so a deposed leader's tail cannot corrupt the replica.
//! `crc` is [`checksum`] over the epoch bytes followed by the payload.
//! [`Wal::replay`] walks frames from the start and stops at the first
//! short, torn, or corrupt frame — exactly the durable prefix an fsync'd
//! file would guarantee — returning the decoded records plus a typed
//! [`WalTruncation`] describing the discarded tail, if any.
//!
//! Frames also carry an *absolute* index that survives
//! [`clear`](Wal::clear) (snapshot compaction): the shipping channel keeps
//! a cursor of absolute indexes, so a snapshot on the leader cannot make
//! the standby silently skip or re-apply frames.

use std::cell::RefCell;
use std::rc::Rc;

use crate::cluster::node::Node;
use crate::cluster::pod::{PodPhase, PodSpec};
use crate::cluster::resources::ResourceVec;
use crate::cluster::store::EventKind;
use crate::gpu::mig::MigLayout;
use crate::queue::kueue::{ClusterQueue, LocalQueue, PriorityClass};
use crate::sim::clock::Time;
use crate::util::codec::{checksum, CodecError, Dec, Enc, Reader};

/// Shared handle: the store and the queue controller each hold one, the
/// platform holds the third for control-state checkpoints and snapshots.
pub type WalHandle = Rc<RefCell<Wal>>;

/// One logged [`ClusterStore`](crate::cluster::store::ClusterStore)
/// mutation. Each variant mirrors a public mutator's arguments; replay
/// re-invokes the mutator with them (ignoring its `Result` — failed calls
/// were logged too and fail identically on replay, reproducing even the
/// resource-version bumps of rejected transitions).
#[derive(Debug, Clone)]
pub enum StoreOp {
    AddNode { node: Node, at: Time },
    RemoveNode { name: String, at: Time },
    SetNodeReady { name: String, ready: bool, at: Time, msg: String },
    RepartitionGpu { node: String, device: String, layout: MigLayout, at: Time },
    DegradeResource { node: String, resource: String, count: i64, at: Time },
    RecoverResource { node: String, resource: String, give: i64, at: Time },
    CreatePod { spec: PodSpec, at: Time },
    Bind { pod: String, node: String, at: Time },
    MarkRunning { pod: String, at: Time },
    FinishPod { pod: String, phase: PodPhase, at: Time, msg: String },
    EvictPod { pod: String, at: Time, requeue: bool, msg: String },
    CancelPending { pod: String, at: Time, msg: String },
    DeletePod { pod: String, at: Time, msg: String },
    GcFinished { before: Time },
    Record { at: Time, kind: EventKind, object: String, msg: String },
    SetEventCapacity { capacity: usize },
}

/// One logged Kueue mutation (same replay contract as [`StoreOp`]).
#[derive(Debug, Clone)]
pub enum KueueOp {
    AddClusterQueue { cq: ClusterQueue },
    AddLocalQueue { lq: LocalQueue },
    SubmitForUser {
        name: String,
        queue: String,
        user: String,
        priority: PriorityClass,
        requests: ResourceVec,
        at: Time,
    },
    SetFairShare { usage: std::collections::HashMap<String, f64> },
    AdjustNominal { queue: String, add: ResourceVec, remove: ResourceVec },
    AdmitPass { at: Time },
    Requeue { name: String, at: Time },
    Finish { name: String, at: Time },
    SetTransitionCapacity { capacity: usize },
    SubmitGang {
        name: String,
        queue: String,
        user: String,
        priority: PriorityClass,
        members: Vec<(String, ResourceVec)>,
        at: Time,
    },
}

/// A log entry: a store op, a queue op, or an opaque control-plane
/// checkpoint blob (facade-local state the platform serializes itself).
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    Store(StoreOp),
    Kueue(KueueOp),
    Control(Vec<u8>),
}

// ------------------------------------------------------------------ codecs

impl PartialEq for StoreOp {
    fn eq(&self, other: &Self) -> bool {
        self.to_bytes() == other.to_bytes()
    }
}

impl Enc for StoreOp {
    fn enc(&self, b: &mut Vec<u8>) {
        match self {
            StoreOp::AddNode { node, at } => {
                b.push(0);
                node.enc(b);
                at.enc(b);
            }
            StoreOp::RemoveNode { name, at } => {
                b.push(1);
                name.enc(b);
                at.enc(b);
            }
            StoreOp::SetNodeReady { name, ready, at, msg } => {
                b.push(2);
                name.enc(b);
                ready.enc(b);
                at.enc(b);
                msg.enc(b);
            }
            StoreOp::RepartitionGpu { node, device, layout, at } => {
                b.push(3);
                node.enc(b);
                device.enc(b);
                layout.enc(b);
                at.enc(b);
            }
            StoreOp::DegradeResource { node, resource, count, at } => {
                b.push(4);
                node.enc(b);
                resource.enc(b);
                count.enc(b);
                at.enc(b);
            }
            StoreOp::RecoverResource { node, resource, give, at } => {
                b.push(5);
                node.enc(b);
                resource.enc(b);
                give.enc(b);
                at.enc(b);
            }
            StoreOp::CreatePod { spec, at } => {
                b.push(6);
                spec.enc(b);
                at.enc(b);
            }
            StoreOp::Bind { pod, node, at } => {
                b.push(7);
                pod.enc(b);
                node.enc(b);
                at.enc(b);
            }
            StoreOp::MarkRunning { pod, at } => {
                b.push(8);
                pod.enc(b);
                at.enc(b);
            }
            StoreOp::FinishPod { pod, phase, at, msg } => {
                b.push(9);
                pod.enc(b);
                phase.enc(b);
                at.enc(b);
                msg.enc(b);
            }
            StoreOp::EvictPod { pod, at, requeue, msg } => {
                b.push(10);
                pod.enc(b);
                at.enc(b);
                requeue.enc(b);
                msg.enc(b);
            }
            StoreOp::CancelPending { pod, at, msg } => {
                b.push(11);
                pod.enc(b);
                at.enc(b);
                msg.enc(b);
            }
            StoreOp::DeletePod { pod, at, msg } => {
                b.push(12);
                pod.enc(b);
                at.enc(b);
                msg.enc(b);
            }
            StoreOp::GcFinished { before } => {
                b.push(13);
                before.enc(b);
            }
            StoreOp::Record { at, kind, object, msg } => {
                b.push(14);
                at.enc(b);
                kind.enc(b);
                object.enc(b);
                msg.enc(b);
            }
            StoreOp::SetEventCapacity { capacity } => {
                b.push(15);
                capacity.enc(b);
            }
        }
    }
}

impl Dec for StoreOp {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => StoreOp::AddNode { node: Dec::dec(r)?, at: Dec::dec(r)? },
            1 => StoreOp::RemoveNode { name: Dec::dec(r)?, at: Dec::dec(r)? },
            2 => StoreOp::SetNodeReady {
                name: Dec::dec(r)?,
                ready: Dec::dec(r)?,
                at: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            3 => StoreOp::RepartitionGpu {
                node: Dec::dec(r)?,
                device: Dec::dec(r)?,
                layout: Dec::dec(r)?,
                at: Dec::dec(r)?,
            },
            4 => StoreOp::DegradeResource {
                node: Dec::dec(r)?,
                resource: Dec::dec(r)?,
                count: Dec::dec(r)?,
                at: Dec::dec(r)?,
            },
            5 => StoreOp::RecoverResource {
                node: Dec::dec(r)?,
                resource: Dec::dec(r)?,
                give: Dec::dec(r)?,
                at: Dec::dec(r)?,
            },
            6 => StoreOp::CreatePod { spec: Dec::dec(r)?, at: Dec::dec(r)? },
            7 => StoreOp::Bind { pod: Dec::dec(r)?, node: Dec::dec(r)?, at: Dec::dec(r)? },
            8 => StoreOp::MarkRunning { pod: Dec::dec(r)?, at: Dec::dec(r)? },
            9 => StoreOp::FinishPod {
                pod: Dec::dec(r)?,
                phase: Dec::dec(r)?,
                at: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            10 => StoreOp::EvictPod {
                pod: Dec::dec(r)?,
                at: Dec::dec(r)?,
                requeue: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            11 => StoreOp::CancelPending {
                pod: Dec::dec(r)?,
                at: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            12 => StoreOp::DeletePod {
                pod: Dec::dec(r)?,
                at: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            13 => StoreOp::GcFinished { before: Dec::dec(r)? },
            14 => StoreOp::Record {
                at: Dec::dec(r)?,
                kind: Dec::dec(r)?,
                object: Dec::dec(r)?,
                msg: Dec::dec(r)?,
            },
            15 => StoreOp::SetEventCapacity { capacity: Dec::dec(r)? },
            t => return Err(CodecError(format!("bad store op tag {t}"))),
        })
    }
}

impl PartialEq for KueueOp {
    fn eq(&self, other: &Self) -> bool {
        self.to_bytes() == other.to_bytes()
    }
}

impl Enc for KueueOp {
    fn enc(&self, b: &mut Vec<u8>) {
        match self {
            KueueOp::AddClusterQueue { cq } => {
                b.push(0);
                cq.enc(b);
            }
            KueueOp::AddLocalQueue { lq } => {
                b.push(1);
                lq.enc(b);
            }
            KueueOp::SubmitForUser { name, queue, user, priority, requests, at } => {
                b.push(2);
                name.enc(b);
                queue.enc(b);
                user.enc(b);
                priority.enc(b);
                requests.enc(b);
                at.enc(b);
            }
            KueueOp::SetFairShare { usage } => {
                b.push(3);
                usage.enc(b);
            }
            KueueOp::AdjustNominal { queue, add, remove } => {
                b.push(4);
                queue.enc(b);
                add.enc(b);
                remove.enc(b);
            }
            KueueOp::AdmitPass { at } => {
                b.push(5);
                at.enc(b);
            }
            KueueOp::Requeue { name, at } => {
                b.push(6);
                name.enc(b);
                at.enc(b);
            }
            KueueOp::Finish { name, at } => {
                b.push(7);
                name.enc(b);
                at.enc(b);
            }
            KueueOp::SetTransitionCapacity { capacity } => {
                b.push(8);
                capacity.enc(b);
            }
            KueueOp::SubmitGang { name, queue, user, priority, members, at } => {
                b.push(9);
                name.enc(b);
                queue.enc(b);
                user.enc(b);
                priority.enc(b);
                members.enc(b);
                at.enc(b);
            }
        }
    }
}

impl Dec for KueueOp {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => KueueOp::AddClusterQueue { cq: Dec::dec(r)? },
            1 => KueueOp::AddLocalQueue { lq: Dec::dec(r)? },
            2 => KueueOp::SubmitForUser {
                name: Dec::dec(r)?,
                queue: Dec::dec(r)?,
                user: Dec::dec(r)?,
                priority: Dec::dec(r)?,
                requests: Dec::dec(r)?,
                at: Dec::dec(r)?,
            },
            3 => KueueOp::SetFairShare { usage: Dec::dec(r)? },
            4 => KueueOp::AdjustNominal {
                queue: Dec::dec(r)?,
                add: Dec::dec(r)?,
                remove: Dec::dec(r)?,
            },
            5 => KueueOp::AdmitPass { at: Dec::dec(r)? },
            6 => KueueOp::Requeue { name: Dec::dec(r)?, at: Dec::dec(r)? },
            7 => KueueOp::Finish { name: Dec::dec(r)?, at: Dec::dec(r)? },
            8 => KueueOp::SetTransitionCapacity { capacity: Dec::dec(r)? },
            9 => KueueOp::SubmitGang {
                name: Dec::dec(r)?,
                queue: Dec::dec(r)?,
                user: Dec::dec(r)?,
                priority: Dec::dec(r)?,
                members: Dec::dec(r)?,
                at: Dec::dec(r)?,
            },
            t => return Err(CodecError(format!("bad kueue op tag {t}"))),
        })
    }
}

impl Enc for WalRecord {
    fn enc(&self, b: &mut Vec<u8>) {
        match self {
            WalRecord::Store(op) => {
                b.push(0);
                op.enc(b);
            }
            WalRecord::Kueue(op) => {
                b.push(1);
                op.enc(b);
            }
            WalRecord::Control(bytes) => {
                b.push(2);
                crate::util::codec::enc_bytes(bytes, b);
            }
        }
    }
}

impl Dec for WalRecord {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => WalRecord::Store(Dec::dec(r)?),
            1 => WalRecord::Kueue(Dec::dec(r)?),
            2 => WalRecord::Control(crate::util::codec::dec_bytes(r)?),
            t => return Err(CodecError(format!("bad wal record tag {t}"))),
        })
    }
}

// --------------------------------------------------------------------- wal

/// One framed record as seen by the shipping channel: its absolute index
/// in the log's lifetime (survives compaction), the writer epoch stamped
/// in the frame header, and the raw payload. The CRC travels with the
/// frame and is re-verified by the standby on ingest.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub index: u64,
    pub epoch: u64,
    /// Header checksum as read from the log — carried as data, so the
    /// standby detects in-flight corruption by recomputing and comparing.
    pub crc: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Recompute the checksum (over epoch bytes ++ payload) and compare
    /// against the carried header value.
    pub fn verify(&self) -> bool {
        frame_crc(self.epoch, &self.payload) == self.crc
    }
}

fn frame_crc(epoch: u64, payload: &[u8]) -> u32 {
    let mut b = Vec::with_capacity(8 + payload.len());
    epoch.enc(&mut b);
    b.extend_from_slice(payload);
    checksum(&b)
}

/// Typed outcome of a replay: decoded records with their writer epochs,
/// plus a typed description of any discarded tail.
#[derive(Debug, Clone)]
pub struct WalReplay {
    pub records: Vec<(u64, WalRecord)>,
    pub truncation: Option<WalTruncation>,
}

/// A replay that stopped early: where, why, and how much survived. The
/// operator-visible form of a torn or corrupt tail — restore and
/// promotion count it as `wal_replay_truncated` and surface a typed
/// Condition instead of a silent warning string.
#[derive(Debug, Clone, PartialEq)]
pub struct WalTruncation {
    /// Byte offset of the first frame that failed to decode.
    pub at_byte: usize,
    /// Intact frames recovered before the damage.
    pub frames_kept: u64,
    /// What failed: torn header, torn payload, checksum, or codec error.
    pub detail: String,
}

impl std::fmt::Display for WalTruncation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({} intact frames kept)", self.detail, self.frames_kept)
    }
}

/// The write-ahead log: an append-only byte buffer of checksummed frames.
#[derive(Debug, Default)]
pub struct Wal {
    buf: Vec<u8>,
    /// Records appended since the buffer was last cleared (stat surface).
    appended: u64,
    /// Writer epoch (leader term) stamped into every appended frame.
    epoch: u64,
    /// Byte offset of each frame currently in the buffer (ship index).
    offsets: Vec<usize>,
    /// Absolute (lifetime) index of `offsets[0]`: [`clear`](Self::clear)
    /// advances it, so ship cursors survive snapshot compaction.
    base_frame: u64,
}

impl Wal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh shared handle for wiring into the store and queue controller.
    pub fn shared() -> WalHandle {
        Rc::new(RefCell::new(Wal::new()))
    }

    /// Frame and append one record under the current writer epoch.
    pub fn append(&mut self, rec: &WalRecord) {
        let payload = rec.to_bytes();
        self.append_frame(self.epoch, &payload);
    }

    /// Append a pre-encoded payload under an explicit writer epoch — the
    /// standby's ingest path re-frames shipped frames through this,
    /// preserving the original writer's epoch instead of stamping its own.
    pub fn append_frame(&mut self, epoch: u64, payload: &[u8]) {
        self.offsets.push(self.buf.len());
        (payload.len() as u32).enc(&mut self.buf);
        frame_crc(epoch, payload).enc(&mut self.buf);
        epoch.enc(&mut self.buf);
        self.buf.extend_from_slice(payload);
        self.appended += 1;
    }

    /// Set the writer epoch stamped into subsequent frames (bumped on
    /// promotion; a deposed leader keeps its stale epoch and is fenced).
    pub fn set_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Absolute index of the next frame to be appended (lifetime counter;
    /// survives [`clear`](Self::clear)).
    pub fn next_frame(&self) -> u64 {
        self.base_frame + self.offsets.len() as u64
    }

    /// Absolute index of the oldest frame still in the buffer.
    pub fn base_frame(&self) -> u64 {
        self.base_frame
    }

    /// Decode the frames with absolute index in `[from, to)` for log
    /// shipping. Indexes outside the retained buffer are clamped (frames
    /// below `base_frame` were compacted into a snapshot the standby gets
    /// separately). Damaged framing is a typed error, never a silent
    /// skip — a gap would desynchronize the standby.
    pub fn frames(&self, from: u64, to: u64) -> Result<Vec<Frame>, CodecError> {
        let lo = from.max(self.base_frame);
        let hi = to.min(self.next_frame());
        let mut out = Vec::new();
        for abs in lo..hi {
            let off = self.offsets[(abs - self.base_frame) as usize];
            let tail = self
                .buf
                .get(off..)
                .ok_or_else(|| CodecError(format!("frame {abs}: offset {off} out of bounds")))?;
            let mut r = Reader::new(tail);
            let len = u32::dec(&mut r)?;
            let crc = u32::dec(&mut r)?;
            let epoch = u64::dec(&mut r)?;
            let payload = r.take(len as usize)?;
            if frame_crc(epoch, payload) != crc {
                return Err(CodecError(format!("frame {abs}: CRC mismatch at ship time")));
            }
            out.push(Frame { index: abs, epoch, crc, payload: payload.to_vec() });
        }
        Ok(out)
    }

    /// Decode every intact frame from the start of the log. Stops at the
    /// first short header, truncated payload, checksum mismatch, or
    /// undecodable payload — the torn tail a crash mid-append leaves —
    /// reporting it as a typed [`WalTruncation`]: everything before the
    /// tear is the durable prefix.
    pub fn replay_report(&self) -> WalReplay {
        let mut records: Vec<(u64, WalRecord)> = Vec::new();
        let mut r = Reader::new(&self.buf);
        macro_rules! truncated {
            ($at:expr, $($detail:tt)*) => {
                return WalReplay {
                    truncation: Some(WalTruncation {
                        at_byte: $at,
                        frames_kept: records.len() as u64,
                        detail: format!($($detail)*),
                    }),
                    records,
                }
            };
        }
        while !r.is_empty() {
            let offset = self.buf.len() - r.remaining();
            let header = (u32::dec(&mut r), u32::dec(&mut r), u64::dec(&mut r));
            let (len, crc, epoch) = match header {
                (Ok(len), Ok(crc), Ok(epoch)) => (len, crc, epoch),
                _ => truncated!(offset, "torn frame header at byte {offset}"),
            };
            let payload = match r.take(len as usize) {
                Ok(p) => p,
                Err(_) => truncated!(offset, "torn payload at byte {offset} (wanted {len} bytes)"),
            };
            if frame_crc(epoch, payload) != crc {
                truncated!(offset, "checksum mismatch at byte {offset}");
            }
            match WalRecord::from_bytes(payload) {
                Ok(rec) => records.push((epoch, rec)),
                Err(e) => truncated!(offset, "undecodable record at byte {offset}: {e}"),
            }
        }
        WalReplay { records, truncation: None }
    }

    /// Back-compat surface over [`replay_report`](Self::replay_report):
    /// records without epochs, truncation flattened to a warning string.
    pub fn replay(&self) -> (Vec<WalRecord>, Option<String>) {
        let rep = self.replay_report();
        (
            rep.records.into_iter().map(|(_, rec)| rec).collect(),
            rep.truncation.map(|t| t.detail),
        )
    }

    /// Drop every record (after the state it covers was snapshotted).
    /// Advances `base_frame` so absolute ship cursors stay meaningful.
    pub fn clear(&mut self) {
        self.base_frame += self.offsets.len() as u64;
        self.offsets.clear();
        self.buf.clear();
        self.appended = 0;
    }

    pub fn len_bytes(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Records appended since the last [`clear`](Self::clear).
    pub fn appended(&self) -> u64 {
        self.appended
    }

    /// Test hook: keep only the first `keep` bytes — a torn write.
    /// Frames starting at or past the cut vanish from the ship index too
    /// (a torn write never produced them on the durable device).
    pub fn truncate_bytes(&mut self, keep: usize) {
        self.buf.truncate(keep);
        self.offsets.retain(|&o| o < keep);
    }

    /// Test hook: flip one byte — simulated media corruption.
    pub fn corrupt_byte(&mut self, at: usize) {
        if let Some(b) = self.buf.get_mut(at) {
            *b ^= 0xff;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::Payload;

    fn sample_ops() -> Vec<WalRecord> {
        vec![
            WalRecord::Store(StoreOp::CreatePod {
                spec: PodSpec::new(
                    "p1",
                    ResourceVec::cpu_millis(500),
                    Payload::Sleep { duration: 5.0 },
                ),
                at: 1.0,
            }),
            WalRecord::Store(StoreOp::Bind { pod: "p1".into(), node: "n1".into(), at: 2.0 }),
            WalRecord::Kueue(KueueOp::AdmitPass { at: 3.0 }),
            WalRecord::Control(vec![1, 2, 3, 4]),
        ]
    }

    #[test]
    fn append_replay_roundtrip() {
        let mut w = Wal::new();
        for rec in sample_ops() {
            w.append(&rec);
        }
        assert_eq!(w.appended(), 4);
        let (recs, warn) = w.replay();
        assert!(warn.is_none(), "{warn:?}");
        assert_eq!(recs, sample_ops());
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.replay().0.len(), 0);
    }

    #[test]
    fn torn_tail_keeps_durable_prefix() {
        let mut w = Wal::new();
        for rec in sample_ops() {
            w.append(&rec);
        }
        // cut into the last frame's payload: 3 intact records survive
        w.truncate_bytes(w.len_bytes() - 2);
        let (recs, warn) = w.replay();
        assert_eq!(recs.len(), 3);
        assert!(warn.unwrap().contains("torn"));
        // cut into a frame header
        w.truncate_bytes(3);
        let (recs, warn) = w.replay();
        assert!(recs.is_empty());
        assert!(warn.unwrap().contains("torn frame header"));
    }

    #[test]
    fn corrupt_byte_stops_replay_at_bad_frame() {
        let mut w = Wal::new();
        for rec in sample_ops() {
            w.append(&rec);
        }
        // flip a byte in the middle of the second frame's payload
        let first_frame_len = {
            let mut probe = Wal::new();
            probe.append(&sample_ops()[0]);
            probe.len_bytes()
        };
        w.corrupt_byte(first_frame_len + 10);
        let (recs, warn) = w.replay();
        assert_eq!(recs.len(), 1, "only the frame before the corruption survives");
        assert!(warn.unwrap().contains("checksum mismatch"));
    }

    #[test]
    fn frames_carry_epochs_and_absolute_indexes_across_compaction() {
        let mut w = Wal::new();
        w.set_epoch(3);
        let ops = sample_ops();
        w.append(&ops[0]);
        w.append(&ops[1]);
        assert_eq!((w.base_frame(), w.next_frame()), (0, 2));
        let frames = w.frames(0, w.next_frame()).unwrap();
        assert_eq!(frames.len(), 2);
        assert!(frames.iter().all(|f| f.epoch == 3));
        assert_eq!(frames[1].index, 1);
        assert_eq!(frames[1].payload, ops[1].to_bytes());
        // replay surfaces the epochs too
        let rep = w.replay_report();
        assert!(rep.truncation.is_none());
        assert_eq!(rep.records[0].0, 3);
        // compaction advances the absolute base; old cursors clamp
        w.clear();
        w.set_epoch(4);
        w.append(&ops[2]);
        assert_eq!((w.base_frame(), w.next_frame()), (2, 3));
        let tail = w.frames(0, w.next_frame()).unwrap();
        assert_eq!(tail.len(), 1, "compacted frames are not re-shipped");
        assert_eq!((tail[0].index, tail[0].epoch), (2, 4));
        // an explicit-epoch re-frame (standby ingest) preserves the
        // original writer's epoch
        w.append_frame(3, &ops[3].to_bytes());
        let f = w.frames(3, 4).unwrap().remove(0);
        assert_eq!(f.epoch, 3);
        assert_eq!(f.crc, frame_crc(3, &ops[3].to_bytes()));
        assert!(f.verify());
    }

    #[test]
    fn replay_report_truncation_is_typed() {
        let mut w = Wal::new();
        for rec in sample_ops() {
            w.append(&rec);
        }
        let len = w.len_bytes();
        w.truncate_bytes(len - 2);
        let rep = w.replay_report();
        let t = rep.truncation.expect("torn tail must be reported");
        assert_eq!(t.frames_kept, 3);
        assert_eq!(rep.records.len(), 3);
        assert!(t.detail.contains("torn"), "{t}");
        assert!(t.at_byte < len);
    }

    /// Fuzz-style sweep: flipping any single byte of the log must never
    /// panic replay — every outcome is a clean prefix plus a typed
    /// truncation (or, if the flip lands in a payload that still decodes,
    /// a checksum rejection). The durability-critical decode surface has
    /// no unwrap that hostile bytes can reach.
    #[test]
    fn single_byte_corruption_never_panics_replay() {
        let pristine = {
            let mut w = Wal::new();
            w.set_epoch(2);
            for rec in sample_ops() {
                w.append(&rec);
            }
            w
        };
        let total = pristine.len_bytes();
        let intact = pristine.replay_report().records.len();
        for at in 0..total {
            let mut w = Wal::new();
            w.set_epoch(2);
            for rec in sample_ops() {
                w.append(&rec);
            }
            w.corrupt_byte(at);
            let rep = w.replay_report();
            assert!(
                rep.records.len() <= intact,
                "byte {at}: corruption must never add records"
            );
            if rep.records.len() < intact {
                assert!(rep.truncation.is_some(), "byte {at}: lost records need a report");
            }
            // shipping the damaged range errors instead of panicking
            let _ = w.frames(0, w.next_frame());
        }
    }

    #[test]
    fn store_op_codec_covers_every_variant() {
        use crate::cluster::node::Node;
        use crate::gpu::{GpuModel, MigLayout};
        let ops = vec![
            StoreOp::AddNode {
                node: Node::physical("n1", 8, 32 << 30, 1 << 40, vec![]),
                at: 0.0,
            },
            StoreOp::RemoveNode { name: "n1".into(), at: 1.0 },
            StoreOp::SetNodeReady { name: "n1".into(), ready: false, at: 2.0, msg: "c".into() },
            StoreOp::RepartitionGpu {
                node: "n1".into(),
                device: "g0".into(),
                layout: MigLayout::max_sharing(GpuModel::A100_40GB).unwrap(),
                at: 3.0,
            },
            StoreOp::DegradeResource {
                node: "n1".into(),
                resource: "nvidia.com/gpu".into(),
                count: 1,
                at: 4.0,
            },
            StoreOp::RecoverResource {
                node: "n1".into(),
                resource: "nvidia.com/gpu".into(),
                give: 1,
                at: 5.0,
            },
            StoreOp::CreatePod {
                spec: PodSpec::new("p", ResourceVec::cpu_millis(1), Payload::Burn { flops: 1.0 }),
                at: 6.0,
            },
            StoreOp::Bind { pod: "p".into(), node: "n1".into(), at: 7.0 },
            StoreOp::MarkRunning { pod: "p".into(), at: 8.0 },
            StoreOp::FinishPod {
                pod: "p".into(),
                phase: PodPhase::Succeeded,
                at: 9.0,
                msg: "ok".into(),
            },
            StoreOp::EvictPod { pod: "p".into(), at: 10.0, requeue: true, msg: "e".into() },
            StoreOp::CancelPending { pod: "p".into(), at: 11.0, msg: "c".into() },
            StoreOp::DeletePod { pod: "p".into(), at: 12.0, msg: "d".into() },
            StoreOp::GcFinished { before: 13.0 },
            StoreOp::Record {
                at: 14.0,
                kind: EventKind::PodUnschedulable,
                object: "p".into(),
                msg: "no fit".into(),
            },
            StoreOp::SetEventCapacity { capacity: 64 },
        ];
        for op in ops {
            let bytes = op.to_bytes();
            let back = StoreOp::from_bytes(&bytes).unwrap();
            assert_eq!(back, op);
        }
    }

    #[test]
    fn kueue_op_codec_covers_every_variant() {
        let mut usage = std::collections::HashMap::new();
        usage.insert("alice".to_string(), 1.5);
        let ops = vec![
            KueueOp::AddClusterQueue {
                cq: ClusterQueue {
                    name: "cq".into(),
                    cohort: Some("co".into()),
                    nominal: ResourceVec::cpu_millis(1000),
                    used: ResourceVec::new(),
                    can_borrow: true,
                    can_lend: false,
                },
            },
            KueueOp::AddLocalQueue {
                lq: LocalQueue { name: "lq".into(), cluster_queue: "cq".into() },
            },
            KueueOp::SubmitForUser {
                name: "w".into(),
                queue: "lq".into(),
                user: "alice".into(),
                priority: PriorityClass::Interactive,
                requests: ResourceVec::cpu_millis(500),
                at: 1.0,
            },
            KueueOp::SetFairShare { usage },
            KueueOp::AdjustNominal {
                queue: "cq".into(),
                add: ResourceVec::cpu_millis(1),
                remove: ResourceVec::new(),
            },
            KueueOp::AdmitPass { at: 2.0 },
            KueueOp::Requeue { name: "w".into(), at: 3.0 },
            KueueOp::Finish { name: "w".into(), at: 4.0 },
            KueueOp::SetTransitionCapacity { capacity: 128 },
            KueueOp::SubmitGang {
                name: "g".into(),
                queue: "lq".into(),
                user: "alice".into(),
                priority: PriorityClass::Batch,
                members: vec![
                    ("g-0".into(), ResourceVec::cpu_millis(250)),
                    ("g-1".into(), ResourceVec::cpu_millis(250)),
                ],
                at: 5.0,
            },
        ];
        for op in ops {
            let bytes = op.to_bytes();
            let back = KueueOp::from_bytes(&bytes).unwrap();
            assert_eq!(back, op);
        }
    }
}
