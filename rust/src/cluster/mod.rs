//! Kubernetes-like cluster substrate (DESIGN.md S9/S10): resource model,
//! nodes, pods, the state store, the filter/score scheduler, and the kubelet
//! lifecycle driver.

pub mod kubelet;
pub mod node;
pub mod pod;
pub mod replication;
pub mod resources;
pub mod scheduler;
pub mod shard;
pub mod store;
pub mod wal;

pub use node::Node;
pub use pod::{Pod, PodPhase, PodSpec};
pub use resources::ResourceVec;
pub use scheduler::Scheduler;
pub use shard::{LedgerStats, RebalancePhase, RebalancePlan, Reservation, ReservationLedger, ShardRouter};
pub use store::ClusterStore;
