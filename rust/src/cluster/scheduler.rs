//! The pod scheduler: a filter/score pipeline in the style of
//! kube-scheduler's framework, with GPU-aware bin-packing.
//!
//! Filters: node readiness, taint/toleration, node-selector match, resource
//! fit (including MIG extended resources).  Scoring: for accelerator pods we
//! *bin-pack* (most-allocated wins) so whole GPUs stay free for big jobs —
//! the policy the AI_INFN operators run to keep A100s partitionable; for
//! CPU-only pods we *spread* (least-allocated) to protect interactive
//! latency. Ties break lexicographically for determinism.

use crate::cluster::pod::PodSpec;
use crate::cluster::resources::{ResourceVec, CPU, MEMORY};
use crate::cluster::store::ClusterStore;

/// Why a pod could not be placed (surfaced in events and the Kueue requeue).
#[derive(Debug, Clone, PartialEq)]
pub enum Unschedulable {
    /// No node passed the filters at all (wrong selectors / no such resource).
    NoFeasibleNode,
    /// Nodes exist but lack free capacity right now.
    InsufficientCapacity,
}

/// Scheduling outcome.
pub type Decision = Result<String, Unschedulable>;

/// Policy knobs.
#[derive(Debug, Clone)]
pub struct SchedPolicy {
    /// Bin-pack accelerator pods (true = AI_INFN default).
    pub binpack_gpu: bool,
    /// Spread CPU-only pods.
    pub spread_cpu: bool,
    /// Prefer physical nodes; consider virtual (InterLink) nodes only when
    /// no physical node currently fits — the offloading policy of §3.
    pub prefer_physical: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy { binpack_gpu: true, spread_cpu: true, prefer_physical: true }
    }
}

/// The scheduler. Stateless between calls except the policy.
#[derive(Debug, Default)]
pub struct Scheduler {
    pub policy: SchedPolicy,
}

impl Scheduler {
    pub fn new(policy: SchedPolicy) -> Self {
        Scheduler { policy }
    }

    /// Does the pod request any extended (device) resource?
    fn wants_device(spec: &PodSpec) -> bool {
        spec.requests
            .iter()
            .any(|(k, _)| k != CPU && k != MEMORY && k != crate::cluster::resources::STORAGE)
    }

    /// Pick a node for `spec`, or say why not. Does not mutate the store.
    /// With `prefer_physical`, virtual (InterLink) nodes are considered only
    /// when no physical node can host the pod right now.
    pub fn select_node(&self, store: &ClusterStore, spec: &PodSpec) -> Decision {
        if self.policy.prefer_physical {
            match self.select_node_filtered(store, spec, Some(false)) {
                Ok(node) => return Ok(node),
                Err(_) => {
                    return match self.select_node_filtered(store, spec, Some(true)) {
                        Ok(node) => Ok(node),
                        // report the *combined* feasibility verdict
                        Err(Unschedulable::NoFeasibleNode) => {
                            self.select_node_filtered(store, spec, None)
                        }
                        Err(e) => Err(e),
                    };
                }
            }
        }
        self.select_node_filtered(store, spec, None)
    }

    /// Do the node-level filters (readiness, taints, selector, the
    /// physical/virtual restriction) admit this node for `spec`?
    fn node_admits(node: &crate::cluster::node::Node, spec: &PodSpec, virtual_only: Option<bool>) -> bool {
        if let Some(want_virtual) = virtual_only {
            if node.virtual_node != want_virtual {
                return false;
            }
        }
        if !node.ready {
            return false;
        }
        // taints: every node taint must be tolerated
        if !node.taints.iter().all(|t| spec.tolerations.iter().any(|k| *k == t.key)) {
            return false;
        }
        // node selector
        spec.node_selector
            .iter()
            .all(|(k, v)| node.labels.get(k).map(|x| x == v).unwrap_or(false))
    }

    /// `virtual_only`: Some(false) = physical nodes only; Some(true) =
    /// virtual nodes only; None = all nodes.
    ///
    /// Candidate pruning: instead of walking every node, the store's
    /// free-capacity index yields only nodes that can currently fit the
    /// request's most selective resource; candidates are then evaluated in
    /// name order so the winner is identical to the former full scan (the
    /// golden-trace determinism contract).
    fn select_node_filtered(
        &self,
        store: &ClusterStore,
        spec: &PodSpec,
        virtual_only: Option<bool>,
    ) -> Decision {
        let mut best: Option<(f64, &str)> = None;
        let wants_device = Self::wants_device(spec);

        // feasibility pruning via the free-capacity index (empty requests
        // fit everywhere — fall back to the full node list, already sorted)
        let candidates: Vec<&str> = match spec
            .requests
            .iter()
            .min_by_key(|(k, _)| store.free_index_size(k))
        {
            Some((res, qty)) => {
                let mut v: Vec<&str> = store.nodes_with_free_at_least(res, qty).collect();
                if v.len() == store.node_count() {
                    // nothing pruned: walk the name-ordered node map
                    // directly instead of paying a sort
                    store.nodes().map(|n| n.name.as_str()).collect()
                } else {
                    v.sort_unstable();
                    v
                }
            }
            None => store.nodes().map(|n| n.name.as_str()).collect(),
        };

        for name in candidates {
            let Some(node) = store.node(name) else { continue };
            if !Self::node_admits(node, spec, virtual_only) {
                continue;
            }
            let Some(free) = store.free_on(&node.name) else { continue };
            if !spec.requests.fits_in(free) {
                continue;
            }

            // score: fraction of node already allocated (dominant resource)
            let used = node.allocatable.checked_sub(free).unwrap_or_default();
            let alloc_share = used.dominant_share(&node.allocatable);
            let score = if wants_device && self.policy.binpack_gpu {
                alloc_share // most-allocated wins
            } else if self.policy.spread_cpu {
                1.0 - alloc_share // least-allocated wins
            } else {
                0.0
            };

            let better = match best {
                None => true,
                Some((s, n)) => {
                    score > s + 1e-12 || (score >= s - 1e-12 && node.name.as_str() < n)
                }
            };
            if better {
                best = Some((score, node.name.as_str()));
            }
        }

        match best {
            Some((_, name)) => Ok(name.to_string()),
            None => {
                // nothing placeable right now — classify the failure: a
                // node that statically fits the request (allocatable, with
                // the same filters) means capacity, not infeasibility.
                // Early-exits on the first hit, so the rare failure path
                // stays cheap.
                let any_feasible = store.nodes().any(|node| {
                    Self::node_admits(node, spec, virtual_only)
                        && spec.requests.fits_in(&node.allocatable)
                });
                if any_feasible {
                    Err(Unschedulable::InsufficientCapacity)
                } else {
                    Err(Unschedulable::NoFeasibleNode)
                }
            }
        }
    }

    /// Scheduling pass: try to place every pending pod (FIFO, priority
    /// first). Returns (placed, unschedulable) pod names.
    pub fn schedule_pending(
        &self,
        store: &mut ClusterStore,
        at: crate::sim::clock::Time,
    ) -> (Vec<String>, Vec<(String, Unschedulable)>) {
        // the store keeps the pending queue in scheduling order (priority
        // desc, FIFO within a class) — detach it for the pass instead of
        // snapshotting + re-sorting + cloning every name per tick
        let pending = store.take_pending();

        let mut placed = Vec::new();
        let mut failed = Vec::new();
        let mut unplaced = Vec::new();
        for entry in pending {
            // decision under the immutable borrow; binding afterwards —
            // avoids cloning the PodSpec per decision (§Perf: -15% on the
            // placement hot loop, see EXPERIMENTS.md)
            let decision = match store.pod(&entry.name) {
                Some(pod) => self.select_node(store, &pod.spec),
                None => continue, // deleted while queued: drop the entry
            };
            match decision {
                Ok(node) => {
                    if store.bind(&entry.name, &node, at).is_ok() {
                        placed.push(entry.name);
                    } else {
                        unplaced.push(entry);
                    }
                }
                Err(e) => {
                    failed.push((entry.name.clone(), e));
                    unplaced.push(entry);
                }
            }
        }
        store.restore_pending(unplaced);
        (placed, failed)
    }
}

/// Build a helper request for tests and examples.
pub fn gpu_request(cpu_millis: i64, mem_bytes: i64, device: &str, count: i64) -> ResourceVec {
    ResourceVec::new()
        .with(CPU, cpu_millis)
        .with(MEMORY, mem_bytes)
        .with(device, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::node::Node;
    use crate::cluster::pod::{Payload, PodSpec};
    use crate::cluster::resources::GPU;
    use crate::gpu::{GpuDevice, GpuModel, MigLayout};

    fn cluster() -> ClusterStore {
        let mut s = ClusterStore::new();
        s.add_node(
            Node::physical("gpu-a", 16, 64 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::TeslaT4)]),
            0.0,
        );
        s.add_node(
            Node::physical("gpu-b", 16, 64 << 30, 1 << 40, vec![GpuDevice::whole("g1", GpuModel::TeslaT4)]),
            0.0,
        );
        s.add_node(Node::physical("cpu-a", 32, 128 << 30, 1 << 40, vec![]), 0.0);
        s
    }

    fn gpu_pod(name: &str) -> PodSpec {
        PodSpec::new(name, gpu_request(1000, 4 << 30, GPU, 1), Payload::Sleep { duration: 10.0 })
    }

    fn cpu_pod(name: &str, millis: i64) -> PodSpec {
        PodSpec::new(name, ResourceVec::cpu_millis(millis), Payload::Sleep { duration: 10.0 })
    }

    #[test]
    fn gpu_pods_binpack_one_node_first() {
        let mut s = cluster();
        let sched = Scheduler::default();
        s.create_pod(gpu_pod("g1"), 0.0);
        let (placed, _) = sched.schedule_pending(&mut s, 0.0);
        let first = s.pod(&placed[0]).unwrap().status.node.clone().unwrap();
        // second GPU pod: the first node is exhausted (1 GPU), goes to other
        s.create_pod(gpu_pod("g2"), 0.0);
        let (placed2, _) = sched.schedule_pending(&mut s, 0.0);
        let second = s.pod(&placed2[0]).unwrap().status.node.clone().unwrap();
        assert_ne!(first, second);
    }

    #[test]
    fn cpu_pods_spread_across_nodes() {
        let mut s = cluster();
        let sched = Scheduler::default();
        s.create_pod(cpu_pod("c1", 4000), 0.0);
        s.create_pod(cpu_pod("c2", 4000), 0.0);
        sched.schedule_pending(&mut s, 0.0);
        let n1 = s.pod("c1").unwrap().status.node.clone().unwrap();
        let n2 = s.pod("c2").unwrap().status.node.clone().unwrap();
        assert_ne!(n1, n2, "spread policy must choose different nodes");
    }

    #[test]
    fn respects_node_selector_and_reports_no_feasible() {
        let mut s = cluster();
        let sched = Scheduler::default();
        let p = cpu_pod("sel", 100).with_selector("kubernetes.io/hostname", "does-not-exist");
        let d = sched.select_node(&s, &p);
        assert_eq!(d, Err(Unschedulable::NoFeasibleNode));
        let p2 = cpu_pod("sel2", 100).with_selector("kubernetes.io/hostname", "cpu-a");
        assert_eq!(sched.select_node(&s, &p2).unwrap(), "cpu-a");
        let _ = &mut s;
    }

    #[test]
    fn capacity_exhaustion_reports_insufficient() {
        let mut s = cluster();
        let sched = Scheduler::default();
        s.create_pod(gpu_pod("g1"), 0.0);
        s.create_pod(gpu_pod("g2"), 0.0);
        sched.schedule_pending(&mut s, 0.0);
        // both T4s taken; a third GPU pod is capacity-blocked, not infeasible
        let d = sched.select_node(&s, &gpu_pod("g3"));
        assert_eq!(d, Err(Unschedulable::InsufficientCapacity));
    }

    #[test]
    fn tainted_virtual_node_needs_toleration() {
        let mut s = cluster();
        s.add_node(
            Node::virtual_node("vk-leonardo", ResourceVec::cpu_millis(1_000_000)),
            0.0,
        );
        let sched = Scheduler::default();
        // huge CPU pod fits only the virtual node but lacks toleration
        let p = cpu_pod("big", 500_000);
        assert_eq!(sched.select_node(&s, &p), Err(Unschedulable::NoFeasibleNode));
        let p_tol = cpu_pod("big2", 500_000).with_toleration("virtual-node.interlink/no-schedule");
        assert_eq!(sched.select_node(&s, &p_tol).unwrap(), "vk-leonardo");
    }

    #[test]
    fn mig_slices_schedule_onto_partitioned_gpu() {
        let mut s = ClusterStore::new();
        let mut gpu = GpuDevice::whole("g0", GpuModel::A100_40GB);
        gpu.repartition(MigLayout::max_sharing(GpuModel::A100_40GB).unwrap()).unwrap();
        s.add_node(Node::physical("a100-node", 32, 128 << 30, 1 << 40, vec![gpu]), 0.0);
        let sched = Scheduler::default();
        for i in 0..7 {
            let p = PodSpec::new(
                format!("mig{i}"),
                gpu_request(500, 2 << 30, "nvidia.com/mig-1g.5gb", 1),
                Payload::Sleep { duration: 5.0 },
            );
            s.create_pod(p, 0.0);
        }
        let (placed, failed) = sched.schedule_pending(&mut s, 0.0);
        assert_eq!(placed.len(), 7, "exactly 7 MIG users fit: {failed:?}");
        // the 8th is capacity-blocked
        let p8 = PodSpec::new(
            "mig8",
            gpu_request(500, 2 << 30, "nvidia.com/mig-1g.5gb", 1),
            Payload::Sleep { duration: 5.0 },
        );
        assert_eq!(sched.select_node(&s, &p8), Err(Unschedulable::InsufficientCapacity));
    }

    #[test]
    fn priority_orders_the_pending_queue() {
        let mut s = ClusterStore::new();
        s.add_node(Node::physical("n", 3, 16 << 30, 1 << 40, vec![]), 0.0);
        // allocatable cpu = 1000 (3 cores − 2 reserved); only one fits
        let sched = Scheduler::default();
        s.create_pod(cpu_pod("low", 1000).with_priority(0), 0.0);
        s.create_pod(cpu_pod("high", 1000).with_priority(100), 0.0);
        let (placed, _) = sched.schedule_pending(&mut s, 0.0);
        assert_eq!(placed, vec!["high".to_string()]);
    }
}
