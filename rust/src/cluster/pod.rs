//! Pods: the unit of scheduling, carrying resource requests, placement
//! constraints, and the *payload* the kubelet will execute (a simulated
//! duration or a real ML job against the PJRT runtime).

use std::collections::BTreeMap;

use crate::cluster::resources::ResourceVec;
use crate::sim::clock::Time;
use crate::util::codec::{CodecError, Dec, Enc, Reader};

/// What the pod actually does once it runs.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Sleep for a fixed active duration (simulation mode).
    Sleep { duration: Time },
    /// Interactive session: runs until culled/stopped (no natural end).
    Session { idle_after: Time },
    /// ML payload executed for real through the PJRT runtime
    /// (hardware-in-the-loop mode). `artifact` names a manifest entry.
    MlJob { artifact: String, steps: u32 },
    /// Synthetic compute with a known FLOP count (cost-model driven).
    Burn { flops: f64 },
}

/// Pod lifecycle phases (superset of k8s' with an explicit Evicted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Scheduled,
    Running,
    Succeeded,
    Failed,
    Evicted,
}

impl PodPhase {
    pub fn is_terminal(&self) -> bool {
        matches!(self, PodPhase::Succeeded | PodPhase::Failed)
    }
}

/// Pod specification (immutable after creation).
#[derive(Debug, Clone)]
pub struct PodSpec {
    pub name: String,
    pub namespace: String,
    pub labels: BTreeMap<String, String>,
    pub requests: ResourceVec,
    /// Node-selector labels (all must match).
    pub node_selector: BTreeMap<String, String>,
    /// Taint keys this pod tolerates.
    pub tolerations: Vec<String>,
    pub priority: i32,
    pub payload: Payload,
    /// Owning user/project for accounting.
    pub user: String,
    pub project: String,
}

impl PodSpec {
    pub fn new(name: impl Into<String>, requests: ResourceVec, payload: Payload) -> PodSpec {
        PodSpec {
            name: name.into(),
            namespace: "default".into(),
            labels: BTreeMap::new(),
            requests,
            node_selector: BTreeMap::new(),
            tolerations: Vec::new(),
            priority: 0,
            payload,
            user: "unknown".into(),
            project: "unknown".into(),
        }
    }

    pub fn with_label(mut self, k: &str, v: &str) -> Self {
        self.labels.insert(k.into(), v.into());
        self
    }

    pub fn with_selector(mut self, k: &str, v: &str) -> Self {
        self.node_selector.insert(k.into(), v.into());
        self
    }

    pub fn with_toleration(mut self, key: &str) -> Self {
        self.tolerations.push(key.into());
        self
    }

    pub fn with_priority(mut self, p: i32) -> Self {
        self.priority = p;
        self
    }

    pub fn with_owner(mut self, user: &str, project: &str) -> Self {
        self.user = user.into();
        self.project = project.into();
        self
    }

    pub fn in_namespace(mut self, ns: &str) -> Self {
        self.namespace = ns.into();
        self
    }
}

/// Live pod status tracked by the store.
#[derive(Debug, Clone)]
pub struct PodStatus {
    pub phase: PodPhase,
    pub node: Option<String>,
    pub created_at: Time,
    pub scheduled_at: Option<Time>,
    pub started_at: Option<Time>,
    pub finished_at: Option<Time>,
    pub message: String,
    /// How many times this pod has been evicted and requeued.
    pub evictions: u32,
    /// Whether this pod has already been counted (once) in the persistent
    /// accounting ledger — run-hours may accrue across several eviction
    /// intervals, but the pod itself is tallied on its first accrual.
    pub accounted: bool,
}

impl PodStatus {
    pub fn new(created_at: Time) -> Self {
        PodStatus {
            phase: PodPhase::Pending,
            node: None,
            created_at,
            scheduled_at: None,
            started_at: None,
            finished_at: None,
            message: String::new(),
            evictions: 0,
            accounted: false,
        }
    }

    /// Scheduling latency (pending → scheduled), if scheduled.
    pub fn schedule_latency(&self) -> Option<Time> {
        self.scheduled_at.map(|s| s - self.created_at)
    }
}

/// A pod = spec + status.
#[derive(Debug, Clone)]
pub struct Pod {
    pub spec: PodSpec,
    pub status: PodStatus,
}

// --------------------------------------------------------------- durability

impl Enc for Payload {
    fn enc(&self, b: &mut Vec<u8>) {
        match self {
            Payload::Sleep { duration } => {
                b.push(0);
                duration.enc(b);
            }
            Payload::Session { idle_after } => {
                b.push(1);
                idle_after.enc(b);
            }
            Payload::MlJob { artifact, steps } => {
                b.push(2);
                artifact.enc(b);
                steps.enc(b);
            }
            Payload::Burn { flops } => {
                b.push(3);
                flops.enc(b);
            }
        }
    }
}

impl Dec for Payload {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => Payload::Sleep { duration: Dec::dec(r)? },
            1 => Payload::Session { idle_after: Dec::dec(r)? },
            2 => Payload::MlJob { artifact: Dec::dec(r)?, steps: Dec::dec(r)? },
            3 => Payload::Burn { flops: Dec::dec(r)? },
            t => return Err(CodecError(format!("bad payload tag {t}"))),
        })
    }
}

impl Enc for PodPhase {
    fn enc(&self, b: &mut Vec<u8>) {
        let tag: u8 = match self {
            PodPhase::Pending => 0,
            PodPhase::Scheduled => 1,
            PodPhase::Running => 2,
            PodPhase::Succeeded => 3,
            PodPhase::Failed => 4,
            PodPhase::Evicted => 5,
        };
        b.push(tag);
    }
}

impl Dec for PodPhase {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => PodPhase::Pending,
            1 => PodPhase::Scheduled,
            2 => PodPhase::Running,
            3 => PodPhase::Succeeded,
            4 => PodPhase::Failed,
            5 => PodPhase::Evicted,
            t => return Err(CodecError(format!("bad pod phase tag {t}"))),
        })
    }
}

impl Enc for PodSpec {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.namespace.enc(b);
        self.labels.enc(b);
        self.requests.enc(b);
        self.node_selector.enc(b);
        self.tolerations.enc(b);
        self.priority.enc(b);
        self.payload.enc(b);
        self.user.enc(b);
        self.project.enc(b);
    }
}

impl Dec for PodSpec {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(PodSpec {
            name: Dec::dec(r)?,
            namespace: Dec::dec(r)?,
            labels: Dec::dec(r)?,
            requests: Dec::dec(r)?,
            node_selector: Dec::dec(r)?,
            tolerations: Dec::dec(r)?,
            priority: Dec::dec(r)?,
            payload: Dec::dec(r)?,
            user: Dec::dec(r)?,
            project: Dec::dec(r)?,
        })
    }
}

impl Enc for PodStatus {
    fn enc(&self, b: &mut Vec<u8>) {
        self.phase.enc(b);
        self.node.enc(b);
        self.created_at.enc(b);
        self.scheduled_at.enc(b);
        self.started_at.enc(b);
        self.finished_at.enc(b);
        self.message.enc(b);
        self.evictions.enc(b);
        self.accounted.enc(b);
    }
}

impl Dec for PodStatus {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(PodStatus {
            phase: Dec::dec(r)?,
            node: Dec::dec(r)?,
            created_at: Dec::dec(r)?,
            scheduled_at: Dec::dec(r)?,
            started_at: Dec::dec(r)?,
            finished_at: Dec::dec(r)?,
            message: Dec::dec(r)?,
            evictions: Dec::dec(r)?,
            accounted: Dec::dec(r)?,
        })
    }
}

impl Enc for Pod {
    fn enc(&self, b: &mut Vec<u8>) {
        self.spec.enc(b);
        self.status.enc(b);
    }
}

impl Dec for Pod {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Pod { spec: Dec::dec(r)?, status: Dec::dec(r)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::resources::CPU;

    #[test]
    fn builder_chain() {
        let p = PodSpec::new("p1", ResourceVec::cpu_millis(500), Payload::Sleep { duration: 10.0 })
            .with_label("app", "jupyter")
            .with_selector("zone", "cnaf")
            .with_toleration("virtual-node.interlink/no-schedule")
            .with_priority(100)
            .with_owner("alice", "lhcb")
            .in_namespace("hub");
        assert_eq!(p.requests.get(CPU), 500);
        assert_eq!(p.labels["app"], "jupyter");
        assert_eq!(p.node_selector["zone"], "cnaf");
        assert_eq!(p.priority, 100);
        assert_eq!(p.namespace, "hub");
    }

    #[test]
    fn status_latency() {
        let mut s = PodStatus::new(10.0);
        assert!(s.schedule_latency().is_none());
        s.scheduled_at = Some(12.5);
        assert_eq!(s.schedule_latency(), Some(2.5));
    }

    #[test]
    fn terminal_phases() {
        assert!(PodPhase::Succeeded.is_terminal());
        assert!(PodPhase::Failed.is_terminal());
        assert!(!PodPhase::Evicted.is_terminal());
        assert!(!PodPhase::Running.is_terminal());
    }
}
