//! The cluster state store: nodes + pods + events, with per-node free
//! capacity accounting and a resource-version counter (an etcd-lite).
//!
//! Single-writer semantics: controllers mutate the store through `&mut`
//! (the discrete-event engine is single-threaded), so no locking is needed
//! on the hot path — one of the reasons the scheduler sustains the §Perf
//! placement-rate target on one core.

use std::collections::{BTreeMap, HashMap};

use crate::cluster::node::Node;
use crate::cluster::pod::{Pod, PodPhase, PodSpec, PodStatus};
use crate::cluster::resources::ResourceVec;
use crate::sim::clock::Time;

/// Cluster event record (kubectl-events-like; feeds monitoring/accounting).
#[derive(Debug, Clone)]
pub struct ClusterEvent {
    pub at: Time,
    pub kind: EventKind,
    pub object: String,
    pub message: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    PodCreated,
    PodScheduled,
    PodStarted,
    PodSucceeded,
    PodFailed,
    PodEvicted,
    /// A pending pod could not be placed this pass (reason in the message);
    /// recorded once per (pod, reason) by the placement controller, not
    /// every tick.
    PodUnschedulable,
    /// The pod object was removed from the store entirely (garbage
    /// collection cascade) — distinct from a terminal phase transition.
    PodDeleted,
    NodeAdded,
    NodeRemoved,
    /// Node state changed in place: cordoned/uncordoned, allocatable
    /// degraded or restored (chaos GPU faults), readiness flips.
    NodeModified,
    MigRepartitioned,
}

/// The store.
#[derive(Debug, Default)]
pub struct ClusterStore {
    nodes: BTreeMap<String, Node>,
    /// Free = allocatable − sum(requests of pods assigned & not terminal).
    free: HashMap<String, ResourceVec>,
    pods: HashMap<String, Pod>,
    /// Pending queue in FIFO order of creation (scheduler scans this).
    pending: Vec<String>,
    events: Vec<ClusterEvent>,
    resource_version: u64,
}

impl ClusterStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self) -> u64 {
        self.resource_version += 1;
        self.resource_version
    }

    pub fn resource_version(&self) -> u64 {
        self.resource_version
    }

    // ------------------------------------------------------------- nodes

    pub fn add_node(&mut self, node: Node, at: Time) {
        self.bump();
        self.free.insert(node.name.clone(), node.allocatable.clone());
        self.record(at, EventKind::NodeAdded, &node.name.clone(), "node registered");
        self.nodes.insert(node.name.clone(), node);
    }

    pub fn remove_node(&mut self, name: &str, at: Time) -> Option<Node> {
        self.bump();
        self.free.remove(name);
        let n = self.nodes.remove(name);
        if n.is_some() {
            self.record(at, EventKind::NodeRemoved, name, "node removed");
        }
        n
    }

    pub fn node(&self, name: &str) -> Option<&Node> {
        self.nodes.get(name)
    }

    pub fn node_mut(&mut self, name: &str) -> Option<&mut Node> {
        self.bump();
        self.nodes.get_mut(name)
    }

    /// Flip a node's readiness (cordon/uncordon). Records a `NodeModified`
    /// event when the state actually changes; returns false for unknown
    /// nodes.
    pub fn set_node_ready(&mut self, name: &str, ready: bool, at: Time, msg: &str) -> bool {
        let changed = match self.nodes.get_mut(name) {
            None => return false,
            Some(n) => {
                if n.ready == ready {
                    false
                } else {
                    n.ready = ready;
                    true
                }
            }
        };
        if changed {
            self.bump();
            self.record(at, EventKind::NodeModified, name, msg);
        }
        true
    }

    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.values()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Free (unreserved) capacity on a node.
    pub fn free_on(&self, node: &str) -> Option<&ResourceVec> {
        self.free.get(node)
    }

    /// Recompute a node's free vector after its allocatable changed
    /// (MIG repartition): free = new allocatable − requests of live pods.
    pub fn recompute_free(&mut self, node_name: &str) {
        let Some(node) = self.nodes.get(node_name) else { return };
        let mut free = node.allocatable.clone();
        for p in self.pods.values() {
            if p.status.node.as_deref() == Some(node_name)
                && matches!(p.status.phase, PodPhase::Scheduled | PodPhase::Running)
            {
                free = free.checked_sub(&p.spec.requests).unwrap_or_else(ResourceVec::new);
            }
        }
        self.free.insert(node_name.to_string(), free);
    }

    // -------------------------------------------------------------- pods

    /// Create a pod in Pending and enqueue it for scheduling.
    pub fn create_pod(&mut self, spec: PodSpec, at: Time) -> String {
        self.bump();
        let name = spec.name.clone();
        assert!(
            !self.pods.contains_key(&name),
            "duplicate pod name {name}"
        );
        self.record(at, EventKind::PodCreated, &name, "created");
        self.pods.insert(name.clone(), Pod { spec, status: PodStatus::new(at) });
        self.pending.push(name.clone());
        name
    }

    pub fn pod(&self, name: &str) -> Option<&Pod> {
        self.pods.get(name)
    }

    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    pub fn pending_pods(&self) -> &[String] {
        &self.pending
    }

    /// Bind a pending pod to a node (scheduler decision). Reserves capacity.
    pub fn bind(&mut self, pod_name: &str, node_name: &str, at: Time) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        let free = self
            .free
            .get_mut(node_name)
            .ok_or_else(|| anyhow::anyhow!("no node {node_name}"))?;
        let rem = free
            .checked_sub(&pod.spec.requests)
            .ok_or_else(|| anyhow::anyhow!("insufficient free capacity on {node_name}"))?;
        *free = rem;
        pod.status.phase = PodPhase::Scheduled;
        pod.status.node = Some(node_name.to_string());
        pod.status.scheduled_at = Some(at);
        self.pending.retain(|n| n != pod_name);
        self.record(at, EventKind::PodScheduled, pod_name, node_name);
        Ok(())
    }

    /// Transition Scheduled → Running.
    pub fn mark_running(&mut self, pod_name: &str, at: Time) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Scheduled, "pod {pod_name} not scheduled");
        pod.status.phase = PodPhase::Running;
        pod.status.started_at = Some(at);
        self.record(at, EventKind::PodStarted, pod_name, "started");
        Ok(())
    }

    /// Terminal transition; releases node capacity.
    pub fn finish_pod(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        anyhow::ensure!(phase.is_terminal(), "finish_pod needs terminal phase");
        self.release(pod_name, phase, at, msg)
    }

    /// Evict a running/scheduled pod (releases capacity, back to Pending if
    /// requeue=true, else marked Evicted permanently).
    pub fn evict_pod(&mut self, pod_name: &str, at: Time, requeue: bool, msg: &str) -> anyhow::Result<()> {
        self.release(pod_name, PodPhase::Evicted, at, msg)?;
        if requeue {
            let pod = self.pods.get_mut(pod_name).unwrap();
            pod.status.phase = PodPhase::Pending;
            pod.status.node = None;
            pod.status.scheduled_at = None;
            pod.status.started_at = None;
            pod.status.evictions += 1;
            self.pending.push(pod_name.to_string());
        }
        Ok(())
    }

    /// Cancel a pod that is still Pending (holds no capacity): removes it
    /// from the scheduling queue and marks it Evicted.
    pub fn cancel_pending(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        pod.status.phase = PodPhase::Evicted;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        self.pending.retain(|n| n != pod_name);
        self.record(at, EventKind::PodEvicted, pod_name, msg);
        Ok(())
    }

    fn release(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(
            matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running),
            "pod {pod_name} not live (phase {:?})",
            pod.status.phase
        );
        if let Some(node) = pod.status.node.clone() {
            if let Some(free) = self.free.get_mut(&node) {
                free.add(&pod.spec.requests);
            }
        }
        pod.status.phase = phase;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        let kind = match phase {
            PodPhase::Succeeded => EventKind::PodSucceeded,
            PodPhase::Failed => EventKind::PodFailed,
            PodPhase::Evicted => EventKind::PodEvicted,
            _ => unreachable!(),
        };
        self.record(at, kind, pod_name, msg);
        Ok(())
    }

    /// Remove a pod object entirely (the ownerReferences GC cascade).
    /// Releases reserved capacity if the pod was live, drops it from the
    /// pending queue, and records a `PodDeleted` event.
    pub fn delete_pod(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        if matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running) {
            if let Some(node) = pod.status.node.clone() {
                if let Some(free) = self.free.get_mut(&node) {
                    free.add(&pod.spec.requests);
                }
            }
        }
        self.pods.remove(pod_name);
        self.pending.retain(|n| n != pod_name);
        self.record(at, EventKind::PodDeleted, pod_name, msg);
        Ok(())
    }

    /// Remove terminal pods older than `before` (GC).
    pub fn gc_finished(&mut self, before: Time) -> usize {
        let victims: Vec<String> = self
            .pods
            .iter()
            .filter(|(_, p)| {
                p.status.phase.is_terminal()
                    && p.status.finished_at.map(|t| t < before).unwrap_or(false)
            })
            .map(|(n, _)| n.clone())
            .collect();
        for v in &victims {
            self.pods.remove(v);
        }
        victims.len()
    }

    // ------------------------------------------------------------ events

    pub fn record(&mut self, at: Time, kind: EventKind, object: &str, message: &str) {
        self.events.push(ClusterEvent { at, kind, object: object.to_string(), message: message.to_string() });
    }

    pub fn events(&self) -> &[ClusterEvent] {
        &self.events
    }

    /// Aggregate resource usage: (used, allocatable) summed over nodes
    /// (restricted to physical nodes when `physical_only`).
    pub fn utilization(&self, physical_only: bool) -> (ResourceVec, ResourceVec) {
        let mut total = ResourceVec::new();
        let mut free = ResourceVec::new();
        for n in self.nodes.values() {
            if physical_only && n.virtual_node {
                continue;
            }
            total.add(&n.allocatable);
            if let Some(f) = self.free.get(&n.name) {
                free.add(f);
            }
        }
        let used = total.checked_sub(&free).unwrap_or_else(ResourceVec::new);
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::Payload;
    use crate::cluster::resources::{CPU, GPU};
    use crate::gpu::{GpuDevice, GpuModel};

    fn store_with_node() -> ClusterStore {
        let mut s = ClusterStore::new();
        let n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::TeslaT4)]);
        s.add_node(n, 0.0);
        s
    }

    fn pod(name: &str, cpu: i64, gpu: i64) -> PodSpec {
        let mut req = ResourceVec::cpu_millis(cpu);
        if gpu > 0 {
            req.set(GPU, gpu);
        }
        PodSpec::new(name, req, Payload::Sleep { duration: 5.0 })
    }

    #[test]
    fn bind_reserves_and_finish_releases() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 4000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 0);
        s.mark_running("p1", 2.1).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 7.0, "done").unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.pod("p1").unwrap().status.phase, PodPhase::Succeeded);
    }

    #[test]
    fn bind_rejects_overcommit() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.create_pod(pod("p2", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        let err = s.bind("p2", "n1", 2.0).unwrap_err();
        assert!(err.to_string().contains("insufficient"));
        // p2 still pending
        assert_eq!(s.pending_pods(), &["p2".to_string()]);
    }

    #[test]
    fn evict_requeues_and_releases_capacity() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 0), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.mark_running("p1", 2.5).unwrap();
        s.evict_pod("p1", 3.0, true, "preempted by interactive").unwrap();
        let p = s.pod("p1").unwrap();
        assert_eq!(p.status.phase, PodPhase::Pending);
        assert_eq!(p.status.evictions, 1);
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert!(s.pending_pods().contains(&"p1".to_string()));
    }

    #[test]
    fn duplicate_pod_name_panics() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.create_pod(pod("p1", 100, 0), 0.0);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn utilization_sums_nodes() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 3000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        let (used, total) = s.utilization(true);
        assert_eq!(used.get(CPU), 3000);
        assert_eq!(total.get(CPU), 6000);
    }

    #[test]
    fn delete_pod_releases_capacity_and_removes_record() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.delete_pod("p1", 3.0, "garbage collected").unwrap();
        assert!(s.pod("p1").is_none());
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::PodDeleted);
        assert!(s.delete_pod("p1", 4.0, "again").is_err(), "double delete errors");
        // deleting a pending pod drops it from the scheduling queue
        s.create_pod(pod("p2", 1000, 0), 5.0);
        s.delete_pod("p2", 6.0, "garbage collected").unwrap();
        assert!(s.pending_pods().is_empty());
    }

    #[test]
    fn gc_removes_old_terminal_pods() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        s.mark_running("p1", 0.0).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 5.0, "ok").unwrap();
        assert_eq!(s.gc_finished(4.0), 0);
        assert_eq!(s.gc_finished(6.0), 1);
        assert!(s.pod("p1").is_none());
    }

    #[test]
    fn set_node_ready_records_only_real_changes() {
        let mut s = store_with_node();
        let before = s.events().len();
        assert!(s.set_node_ready("n1", true, 1.0, "noop"));
        assert_eq!(s.events().len(), before, "no event for a no-op flip");
        assert!(s.set_node_ready("n1", false, 2.0, "cordoned"));
        assert!(!s.node("n1").unwrap().ready);
        assert_eq!(s.events().len(), before + 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::NodeModified);
        assert!(!s.set_node_ready("ghost", false, 3.0, "x"));
    }

    #[test]
    fn recompute_free_after_allocatable_change() {
        let mut s = ClusterStore::new();
        let mut n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::A100_40GB)]);
        s.add_node(n.clone(), 0.0);
        s.create_pod(pod("p1", 1000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        // repartition the A100
        n.gpus[0]
            .repartition(crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap())
            .unwrap();
        n.refresh_extended_resources();
        *s.node_mut("n1").unwrap() = n;
        s.recompute_free("n1");
        let f = s.free_on("n1").unwrap();
        assert_eq!(f.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(f.get(CPU), 5000); // 6000 allocatable − 1000 reserved
    }
}
