//! The cluster state store: nodes + pods + events, with per-node free
//! capacity accounting and a resource-version counter (an etcd-lite).
//!
//! Single-writer semantics: controllers mutate the store through `&mut`
//! (the discrete-event engine is single-threaded), so no locking is needed
//! on the hot path — one of the reasons the scheduler sustains the §Perf
//! placement-rate target on one core.
//!
//! Three structures keep the read/schedule hot paths off full scans:
//!
//! * the **event log** is a bounded [`RingLog`] with absolute cursors —
//!   consumers (the API server's watch pump, the reconciler runtime) read
//!   only the suffix since their cursor and get a typed
//!   [`Compacted`](crate::util::ring::Compacted) error if they fell
//!   behind the retained window;
//! * the **pending queue** is kept in scheduling order (priority desc,
//!   FIFO within a class) at insert time, so the scheduler never rebuilds
//!   or clones the priority order per tick;
//! * the **free-capacity index** maps each resource to a sorted
//!   `(free amount, node)` set, updated incrementally on bind/release, so
//!   node selection iterates only nodes that can currently fit a request
//!   instead of every node in the cluster.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::cluster::node::Node;
use crate::cluster::pod::{Pod, PodPhase, PodSpec, PodStatus};
use crate::cluster::resources::ResourceVec;
use crate::cluster::wal::{StoreOp, WalHandle, WalRecord};
use crate::gpu::mig::MigLayout;
use crate::gpu::GpuDevice;
use crate::monitoring::accounting::UsageLedger;
use crate::sim::clock::Time;
use crate::util::codec::{CodecError, Dec, Enc, Reader};
use crate::util::ring::RingLog;

/// Cluster event record (kubectl-events-like; feeds monitoring/accounting).
#[derive(Debug, Clone)]
pub struct ClusterEvent {
    pub at: Time,
    pub kind: EventKind,
    pub object: String,
    pub message: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    PodCreated,
    PodScheduled,
    PodStarted,
    PodSucceeded,
    PodFailed,
    PodEvicted,
    /// A pending pod could not be placed this pass (reason in the message);
    /// recorded once per (pod, reason) by the placement controller, not
    /// every tick.
    PodUnschedulable,
    /// The pod object was removed from the store entirely (garbage
    /// collection cascade) — distinct from a terminal phase transition.
    PodDeleted,
    NodeAdded,
    NodeRemoved,
    /// Node state changed in place: cordoned/uncordoned, allocatable
    /// degraded or restored (chaos GPU faults), readiness flips.
    NodeModified,
    MigRepartitioned,
}

/// One pending-queue entry. The queue is kept sorted (priority desc, FIFO
/// within a class) so scheduling passes read it in order without sorting.
#[derive(Debug, Clone)]
pub(crate) struct PendingPod {
    pub(crate) priority: i32,
    pub(crate) name: String,
}

/// The store.
#[derive(Debug, Default)]
pub struct ClusterStore {
    nodes: BTreeMap<String, Node>,
    /// Free = allocatable − sum(requests of pods assigned & not terminal).
    free: HashMap<String, ResourceVec>,
    pods: HashMap<String, Pod>,
    /// Pending queue in scheduling order: priority desc, then FIFO.
    pending: Vec<PendingPod>,
    /// Bounded event log (ring with absolute cursors).
    events: RingLog<ClusterEvent>,
    resource_version: u64,
    /// resource → sorted (free amount, node) pairs with amount > 0; the
    /// scheduler's feasibility pruning. Maintained incrementally wherever
    /// `free` changes.
    free_index: HashMap<String, BTreeSet<(i64, String)>>,
    /// Persistent per-principal usage, accrued at every terminal-phase
    /// transition — the accounting source of truth that survives pod GC.
    ledger: UsageLedger,
    /// Write-ahead log sink. When attached, every public mutator appends
    /// its op at method entry (before executing) so a crash can be
    /// recovered by replay. Not part of snapshots — the platform
    /// re-attaches after a restore.
    wal: Option<WalHandle>,
    /// Epoch (leader term) of the writer driving this store. Like the
    /// wal handle, not snapshot state — the platform re-sets it after a
    /// restore or promotion.
    writer_epoch: u64,
    /// Mutations from writer epochs below this are fenced (dropped and
    /// counted): the split-brain guard raised at promotion.
    fenced_below: u64,
    /// Stale-epoch mutations rejected at the guard.
    fenced_writes: u64,
}

/// Apply a free-vector change to the inverted capacity index: for every
/// resource whose amount changed, drop the stale `(amount, node)` entry
/// and insert the new one (zero amounts are not indexed).
fn index_update(
    idx: &mut HashMap<String, BTreeSet<(i64, String)>>,
    node: &str,
    old: &ResourceVec,
    new: &ResourceVec,
) {
    for (k, v) in old.iter() {
        let nv = new.get(k);
        if nv != v {
            if let Some(set) = idx.get_mut(k) {
                set.remove(&(v, node.to_string()));
            }
            if nv > 0 {
                idx.entry(k.to_string()).or_default().insert((nv, node.to_string()));
            }
        }
    }
    for (k, v) in new.iter() {
        if old.get(k) == 0 {
            idx.entry(k.to_string()).or_default().insert((v, node.to_string()));
        }
    }
}

impl ClusterStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self) -> u64 {
        self.resource_version += 1;
        self.resource_version
    }

    pub fn resource_version(&self) -> u64 {
        self.resource_version
    }

    // ----------------------------------------------------------- fencing

    /// Set the epoch (leader term) of the writer driving this store.
    /// Promotion bumps it; resurrecting a deposed leader rolls it back.
    pub fn set_writer_epoch(&mut self, epoch: u64) {
        self.writer_epoch = epoch;
    }

    pub fn writer_epoch(&self) -> u64 {
        self.writer_epoch
    }

    /// Raise the split-brain fence: mutations from writer epochs below
    /// `epoch` are dropped at method entry (and counted) from here on.
    pub fn set_fence(&mut self, epoch: u64) {
        self.fenced_below = epoch;
    }

    /// Stale-epoch mutations rejected since the store was created.
    pub fn fenced_writes(&self) -> u64 {
        self.fenced_writes
    }

    /// The mutation-entry guard: true (and counted) when the writer's
    /// epoch predates the fence — the mutation must not execute, and
    /// must not be logged.
    fn fenced(&mut self) -> bool {
        if self.writer_epoch < self.fenced_below {
            self.fenced_writes += 1;
            true
        } else {
            false
        }
    }

    // --------------------------------------------------------------- wal

    /// Attach the write-ahead log: every public mutation from here on is
    /// appended (at method entry) for crash replay.
    pub fn attach_wal(&mut self, wal: WalHandle) {
        self.wal = Some(wal);
    }

    /// Detach the log (replay and snapshot restore run unlogged).
    pub fn detach_wal(&mut self) {
        self.wal = None;
    }

    /// Build and append an op only when a wal is attached — the closure
    /// keeps the clone cost off the wal-less fast path.
    fn log_op(&mut self, op: impl FnOnce() -> StoreOp) {
        if let Some(wal) = &self.wal {
            wal.borrow_mut().append(&WalRecord::Store(op()));
        }
    }

    /// Re-execute one logged op during replay. Results are dropped on the
    /// floor: failed calls were logged at entry too and fail identically on
    /// replay, reproducing even the resource-version bumps of rejected
    /// transitions. Must run with the wal detached, or replay would append
    /// duplicate records.
    pub fn apply_op(&mut self, op: StoreOp) {
        debug_assert!(self.wal.is_none(), "replaying with a wal attached double-logs");
        match op {
            StoreOp::AddNode { node, at } => self.add_node(node, at),
            StoreOp::RemoveNode { name, at } => {
                self.remove_node(&name, at);
            }
            StoreOp::SetNodeReady { name, ready, at, msg } => {
                self.set_node_ready(&name, ready, at, &msg);
            }
            StoreOp::RepartitionGpu { node, device, layout, at } => {
                let _ = self.repartition_gpu(&node, &device, layout, at);
            }
            StoreOp::DegradeResource { node, resource, count, at } => {
                self.degrade_resource(&node, &resource, count, at);
            }
            StoreOp::RecoverResource { node, resource, give, at } => {
                self.recover_resource(&node, &resource, give, at);
            }
            StoreOp::CreatePod { spec, at } => {
                self.create_pod(spec, at);
            }
            StoreOp::Bind { pod, node, at } => {
                let _ = self.bind(&pod, &node, at);
            }
            StoreOp::MarkRunning { pod, at } => {
                let _ = self.mark_running(&pod, at);
            }
            StoreOp::FinishPod { pod, phase, at, msg } => {
                let _ = self.finish_pod(&pod, phase, at, &msg);
            }
            StoreOp::EvictPod { pod, at, requeue, msg } => {
                let _ = self.evict_pod(&pod, at, requeue, &msg);
            }
            StoreOp::CancelPending { pod, at, msg } => {
                let _ = self.cancel_pending(&pod, at, &msg);
            }
            StoreOp::DeletePod { pod, at, msg } => {
                let _ = self.delete_pod(&pod, at, &msg);
            }
            StoreOp::GcFinished { before } => {
                self.gc_finished(before);
            }
            StoreOp::Record { at, kind, object, msg } => {
                self.push_event(at, kind, &object, &msg);
            }
            StoreOp::SetEventCapacity { capacity } => {
                self.set_event_capacity(capacity);
            }
        }
    }

    // ------------------------------------------------------------- nodes

    pub fn add_node(&mut self, node: Node, at: Time) {
        if self.fenced() {
            return;
        }
        self.log_op(|| StoreOp::AddNode { node: node.clone(), at });
        self.bump();
        let old = self.free.get(&node.name).cloned().unwrap_or_default();
        index_update(&mut self.free_index, &node.name, &old, &node.allocatable);
        self.free.insert(node.name.clone(), node.allocatable.clone());
        self.push_event(at, EventKind::NodeAdded, &node.name.clone(), "node registered");
        self.nodes.insert(node.name.clone(), node);
    }

    pub fn remove_node(&mut self, name: &str, at: Time) -> Option<Node> {
        if self.fenced() {
            return None;
        }
        self.log_op(|| StoreOp::RemoveNode { name: name.to_string(), at });
        self.bump();
        if let Some(old) = self.free.remove(name) {
            index_update(&mut self.free_index, name, &old, &ResourceVec::new());
        }
        let n = self.nodes.remove(name);
        if n.is_some() {
            self.push_event(at, EventKind::NodeRemoved, name, "node removed");
        }
        n
    }

    pub fn node(&self, name: &str) -> Option<&Node> {
        self.nodes.get(name)
    }

    pub fn node_mut(&mut self, name: &str) -> Option<&mut Node> {
        self.bump();
        self.nodes.get_mut(name)
    }

    /// Flip a node's readiness (cordon/uncordon). Records a `NodeModified`
    /// event when the state actually changes; returns false for unknown
    /// nodes.
    pub fn set_node_ready(&mut self, name: &str, ready: bool, at: Time, msg: &str) -> bool {
        if self.fenced() {
            return false;
        }
        self.log_op(|| StoreOp::SetNodeReady {
            name: name.to_string(),
            ready,
            at,
            msg: msg.to_string(),
        });
        let changed = match self.nodes.get_mut(name) {
            None => return false,
            Some(n) => {
                if n.ready == ready {
                    false
                } else {
                    n.ready = ready;
                    true
                }
            }
        };
        if changed {
            self.bump();
            self.push_event(at, EventKind::NodeModified, name, msg);
        }
        true
    }

    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.values()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Free (unreserved) capacity on a node.
    pub fn free_on(&self, node: &str) -> Option<&ResourceVec> {
        self.free.get(node)
    }

    /// Names of nodes with at least `qty` free units of `resource`
    /// (ascending free amount; the scheduler sorts candidates by name).
    pub fn nodes_with_free_at_least(
        &self,
        resource: &str,
        qty: i64,
    ) -> impl Iterator<Item = &str> {
        self.free_index
            .get(resource)
            .into_iter()
            .flat_map(move |set| set.range((qty, String::new())..).map(|(_, n)| n.as_str()))
    }

    /// How many nodes currently have any free capacity of `resource`
    /// (index selectivity hint for the scheduler).
    pub fn free_index_size(&self, resource: &str) -> usize {
        self.free_index.get(resource).map(|s| s.len()).unwrap_or(0)
    }

    /// Every installed accelerator with its hosting node, in (node, slot)
    /// order — deterministic because the node map is sorted by name.
    pub fn gpu_devices(&self) -> impl Iterator<Item = (&Node, &GpuDevice)> {
        self.nodes.values().flat_map(|n| n.gpus.iter().map(move |g| (n, g)))
    }

    /// Find a device by id across all nodes.
    pub fn find_gpu(&self, device_id: &str) -> Option<(&Node, &GpuDevice)> {
        self.gpu_devices().find(|(_, g)| g.id == device_id)
    }

    /// Safely apply a new MIG `layout` to device `device_id` on
    /// `node_name` — the only repartition path on a device installed in a
    /// node. Refuses while any of the capacity the device would stop
    /// advertising is still bound by live pods, then swaps the layout,
    /// re-derives the node's extended resources, recomputes free capacity
    /// (maintaining the per-resource free index), and records a
    /// `MigRepartitioned` event for the device plus a `NodeModified` event
    /// for the node. Returns the `(removed, added)` extended-resource
    /// advertisements so callers can rebalance queue quotas.
    pub fn repartition_gpu(
        &mut self,
        node_name: &str,
        device_id: &str,
        layout: MigLayout,
        at: Time,
    ) -> anyhow::Result<(ResourceVec, ResourceVec)> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::RepartitionGpu {
            node: node_name.to_string(),
            device: device_id.to_string(),
            layout: layout.clone(),
            at,
        });
        let node = self
            .nodes
            .get(node_name)
            .ok_or_else(|| anyhow::anyhow!("no node {node_name}"))?;
        let idx = node
            .gpus
            .iter()
            .position(|g| g.id == device_id)
            .ok_or_else(|| anyhow::anyhow!("no device {device_id} on node {node_name}"))?;
        let model = node.gpus[idx].model;
        anyhow::ensure!(!model.is_fpga(), "device {device_id} is an FPGA, not repartitionable");
        let validated = MigLayout::new(model, layout.instances)
            .map_err(|e| anyhow::anyhow!("invalid layout for {device_id}: {e}"))?;
        let old_adv = node.gpus[idx].extended_resources();
        let new_adv = validated.extended_resources();
        // the bound-slices guard: for every resource whose advertisement
        // shrinks, the removed amount must be sitting free on the node —
        // otherwise live pods hold slices of the old layout and swapping
        // it would leak their reserved capacity
        let free = self.free.get(node_name).cloned().unwrap_or_default();
        for (k, v) in old_adv.iter() {
            let shrink = v - new_adv.get(k);
            if shrink > 0 && free.get(k) < shrink {
                anyhow::bail!(
                    "repartition refused: {k} on {device_id} still bound \
                     (free {} < removed {shrink})",
                    free.get(k)
                );
            }
        }
        let label = if validated.enabled() {
            validated.instances.iter().map(|p| p.label()).collect::<Vec<_>>().join("+")
        } else {
            "whole".to_string()
        };
        self.bump();
        let node = self.nodes.get_mut(node_name).unwrap();
        node.gpus[idx].repartition(validated).expect("layout pre-validated");
        node.refresh_extended_resources();
        self.recompute_free(node_name);
        self.push_event(
            at,
            EventKind::NodeModified,
            node_name,
            &format!("mig repartitioned: {device_id} -> {label}"),
        );
        self.push_event(at, EventKind::MigRepartitioned, device_id, &format!("{node_name}: {label}"));
        Ok((old_adv, new_adv))
    }

    /// Recompute a node's free vector after its allocatable changed
    /// (MIG repartition): free = new allocatable − requests of live pods.
    pub fn recompute_free(&mut self, node_name: &str) {
        let Some(node) = self.nodes.get(node_name) else { return };
        let mut free = node.allocatable.clone();
        for p in self.pods.values() {
            if p.status.node.as_deref() == Some(node_name)
                && matches!(p.status.phase, PodPhase::Scheduled | PodPhase::Running)
            {
                free = free.checked_sub(&p.spec.requests).unwrap_or_else(ResourceVec::new);
            }
        }
        let old = self.free.get(node_name).cloned().unwrap_or_default();
        index_update(&mut self.free_index, node_name, &old, &free);
        self.free.insert(node_name.to_string(), free);
    }

    /// Chaos support: remove up to `count` units of `resource` from a
    /// node's allocatable. Clamped to the node's *free* units — degrading
    /// capacity a running pod holds would drive `recompute_free` negative
    /// and (via its empty-vector fallback) zero out the node's CPU and
    /// memory too. Returns the units actually removed.
    pub fn degrade_resource(&mut self, node: &str, resource: &str, count: i64, at: Time) -> i64 {
        if self.fenced() {
            return 0;
        }
        self.log_op(|| StoreOp::DegradeResource {
            node: node.to_string(),
            resource: resource.to_string(),
            count,
            at,
        });
        let free_units = self.free.get(node).map(|f| f.get(resource)).unwrap_or(0);
        self.bump();
        let taken = match self.nodes.get_mut(node) {
            None => 0,
            Some(n) => {
                let avail = n.allocatable.get(resource).min(free_units);
                let take = count.min(avail).max(0);
                if take > 0 {
                    let alloc = n.allocatable.get(resource);
                    n.allocatable.set(resource, alloc - take);
                }
                take
            }
        };
        if taken > 0 {
            self.recompute_free(node);
            self.push_event(
                at,
                EventKind::NodeModified,
                node,
                &format!("gpu degraded: -{taken} {resource}"),
            );
        }
        taken
    }

    /// Chaos support: give back `give` units of `resource` previously
    /// removed by [`degrade_resource`](Self::degrade_resource). The caller
    /// owns the owed-units bookkeeping (the platform's degraded map) and
    /// passes an already-clamped amount.
    pub fn recover_resource(&mut self, node: &str, resource: &str, give: i64, at: Time) {
        if self.fenced() {
            return;
        }
        self.log_op(|| StoreOp::RecoverResource {
            node: node.to_string(),
            resource: resource.to_string(),
            give,
            at,
        });
        self.bump();
        if let Some(n) = self.nodes.get_mut(node) {
            let cur = n.allocatable.get(resource);
            n.allocatable.set(resource, cur + give);
        }
        self.recompute_free(node);
        self.push_event(
            at,
            EventKind::NodeModified,
            node,
            &format!("gpu recovered: +{give} {resource}"),
        );
    }

    // -------------------------------------------------------------- pods

    /// Insert into the pending queue in scheduling order: after every
    /// entry of equal-or-higher priority (priority desc, FIFO within a
    /// class — requeued pods go to the back of their class).
    fn enqueue_pending(&mut self, priority: i32, name: String) {
        let pos = self.pending.partition_point(|e| e.priority >= priority);
        self.pending.insert(pos, PendingPod { priority, name });
    }

    /// Create a pod in Pending and enqueue it for scheduling.
    pub fn create_pod(&mut self, spec: PodSpec, at: Time) -> String {
        if self.fenced() {
            return spec.name;
        }
        self.log_op(|| StoreOp::CreatePod { spec: spec.clone(), at });
        self.bump();
        let name = spec.name.clone();
        assert!(
            !self.pods.contains_key(&name),
            "duplicate pod name {name}"
        );
        self.push_event(at, EventKind::PodCreated, &name, "created");
        let priority = spec.priority;
        self.pods.insert(name.clone(), Pod { spec, status: PodStatus::new(at) });
        self.enqueue_pending(priority, name.clone());
        name
    }

    pub fn pod(&self, name: &str) -> Option<&Pod> {
        self.pods.get(name)
    }

    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    /// Pending pod names in scheduling order (priority desc, FIFO within a
    /// class).
    pub fn pending_pods(&self) -> impl Iterator<Item = &str> {
        self.pending.iter().map(|e| e.name.as_str())
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Detach the pending queue for a scheduling pass (the scheduler walks
    /// it while binding against `&mut self`, without cloning every name).
    /// Unplaced entries must be handed back via [`restore_pending`].
    pub(crate) fn take_pending(&mut self) -> Vec<PendingPod> {
        std::mem::take(&mut self.pending)
    }

    /// Hand back the unplaced suffix of a detached pending queue. Entries
    /// are already in scheduling order and *predate* anything enqueued
    /// while the queue was detached, so they merge in **before** any
    /// equal-priority newcomer (FIFO within a class is preserved).
    pub(crate) fn restore_pending(&mut self, entries: Vec<PendingPod>) {
        if self.pending.is_empty() {
            self.pending = entries;
            return;
        }
        let newcomers = std::mem::replace(&mut self.pending, entries);
        for e in newcomers {
            // enqueue_pending places after every >=-priority entry —
            // i.e. behind the restored (older) members of its class
            self.enqueue_pending(e.priority, e.name);
        }
    }

    /// Bind a pending pod to a node (scheduler decision). Reserves capacity.
    pub fn bind(&mut self, pod_name: &str, node_name: &str, at: Time) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::Bind {
            pod: pod_name.to_string(),
            node: node_name.to_string(),
            at,
        });
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        let free = self
            .free
            .get_mut(node_name)
            .ok_or_else(|| anyhow::anyhow!("no node {node_name}"))?;
        let rem = free
            .checked_sub(&pod.spec.requests)
            .ok_or_else(|| anyhow::anyhow!("insufficient free capacity on {node_name}"))?;
        index_update(&mut self.free_index, node_name, free, &rem);
        *free = rem;
        pod.status.phase = PodPhase::Scheduled;
        pod.status.node = Some(node_name.to_string());
        pod.status.scheduled_at = Some(at);
        self.pending.retain(|e| e.name != pod_name);
        self.push_event(at, EventKind::PodScheduled, pod_name, node_name);
        Ok(())
    }

    /// Transition Scheduled → Running.
    pub fn mark_running(&mut self, pod_name: &str, at: Time) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::MarkRunning { pod: pod_name.to_string(), at });
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Scheduled, "pod {pod_name} not scheduled");
        pod.status.phase = PodPhase::Running;
        pod.status.started_at = Some(at);
        self.push_event(at, EventKind::PodStarted, pod_name, "started");
        Ok(())
    }

    /// Terminal transition; releases node capacity.
    pub fn finish_pod(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::FinishPod {
            pod: pod_name.to_string(),
            phase,
            at,
            msg: msg.to_string(),
        });
        anyhow::ensure!(phase.is_terminal(), "finish_pod needs terminal phase");
        self.release(pod_name, phase, at, msg)
    }

    /// Evict a running/scheduled pod (releases capacity, back to Pending if
    /// requeue=true, else marked Evicted permanently).
    pub fn evict_pod(&mut self, pod_name: &str, at: Time, requeue: bool, msg: &str) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::EvictPod {
            pod: pod_name.to_string(),
            at,
            requeue,
            msg: msg.to_string(),
        });
        self.release(pod_name, PodPhase::Evicted, at, msg)?;
        if requeue {
            let pod = self.pods.get_mut(pod_name).unwrap();
            pod.status.phase = PodPhase::Pending;
            pod.status.node = None;
            pod.status.scheduled_at = None;
            pod.status.started_at = None;
            pod.status.evictions += 1;
            let priority = pod.spec.priority;
            self.enqueue_pending(priority, pod_name.to_string());
        }
        Ok(())
    }

    /// Cancel a pod that is still Pending (holds no capacity): removes it
    /// from the scheduling queue and marks it Evicted.
    pub fn cancel_pending(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::CancelPending {
            pod: pod_name.to_string(),
            at,
            msg: msg.to_string(),
        });
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        pod.status.phase = PodPhase::Evicted;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        self.pending.retain(|e| e.name != pod_name);
        self.push_event(at, EventKind::PodEvicted, pod_name, msg);
        Ok(())
    }

    fn release(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(
            matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running),
            "pod {pod_name} not live (phase {:?})",
            pod.status.phase
        );
        if let Some(node) = pod.status.node.clone() {
            if let Some(free) = self.free.get_mut(&node) {
                let old = free.clone();
                free.add(&pod.spec.requests);
                index_update(&mut self.free_index, &node, &old, free);
            }
        }
        // accrue the run interval into the persistent accounting ledger at
        // the terminal transition — the record survives GC of the pod
        // object, and a zero-hour (same-tick) interval still counts the pod
        if let Some(start) = pod.status.started_at {
            let hours = ((at - start).max(0.0)) / 3600.0;
            let node = pod.status.node.as_deref().and_then(|n| self.nodes.get(n));
            self.ledger.accrue(
                &pod.spec.user,
                &pod.spec.project,
                &pod.spec.requests,
                node,
                hours,
                !pod.status.accounted,
            );
            pod.status.accounted = true;
        }
        pod.status.phase = phase;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        let kind = match phase {
            PodPhase::Succeeded => EventKind::PodSucceeded,
            PodPhase::Failed => EventKind::PodFailed,
            PodPhase::Evicted => EventKind::PodEvicted,
            _ => unreachable!(),
        };
        self.push_event(at, kind, pod_name, msg);
        Ok(())
    }

    /// Remove a pod object entirely (the ownerReferences GC cascade).
    /// Releases reserved capacity if the pod was live, drops it from the
    /// pending queue, and records a `PodDeleted` event.
    pub fn delete_pod(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        if self.fenced() {
            anyhow::bail!("write fenced: writer epoch {} below fence", self.writer_epoch);
        }
        self.log_op(|| StoreOp::DeletePod {
            pod: pod_name.to_string(),
            at,
            msg: msg.to_string(),
        });
        self.bump();
        let pod = self
            .pods
            .get(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        if matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running) {
            if let Some(node) = pod.status.node.clone() {
                if let Some(free) = self.free.get_mut(&node) {
                    let old = free.clone();
                    free.add(&pod.spec.requests);
                    index_update(&mut self.free_index, &node, &old, free);
                }
            }
            // a live pod deleted by the GC cascade still ran: accrue its
            // interval before the object disappears
            if let Some(start) = pod.status.started_at {
                let hours = ((at - start).max(0.0)) / 3600.0;
                let node = pod.status.node.as_deref().and_then(|n| self.nodes.get(n));
                self.ledger.accrue(
                    &pod.spec.user,
                    &pod.spec.project,
                    &pod.spec.requests,
                    node,
                    hours,
                    !pod.status.accounted,
                );
            }
        }
        self.pods.remove(pod_name);
        self.pending.retain(|e| e.name != pod_name);
        self.push_event(at, EventKind::PodDeleted, pod_name, msg);
        Ok(())
    }

    /// Remove terminal pods older than `before` (GC).
    pub fn gc_finished(&mut self, before: Time) -> usize {
        if self.fenced() {
            return 0;
        }
        self.log_op(|| StoreOp::GcFinished { before });
        let victims: Vec<String> = self
            .pods
            .iter()
            .filter(|(_, p)| {
                p.status.phase.is_terminal()
                    && p.status.finished_at.map(|t| t < before).unwrap_or(false)
            })
            .map(|(n, _)| n.clone())
            .collect();
        for v in &victims {
            self.pods.remove(v);
        }
        victims.len()
    }

    // ------------------------------------------------------------ ledger

    /// The persistent accounting ledger: usage accrued at terminal-phase
    /// transitions (finish/evict/delete-while-live), surviving pod GC.
    pub fn usage_ledger(&self) -> &UsageLedger {
        &self.ledger
    }

    // ------------------------------------------------------------ events

    /// Append an out-of-band event from outside the store (controllers
    /// noting e.g. `PodUnschedulable`). Logged to the wal — these events
    /// are part of the durable stream watch consumers replay. Mutators
    /// use the private [`push_event`](Self::push_event) instead: their
    /// events are reproduced by replaying the op that emitted them.
    pub fn record(&mut self, at: Time, kind: EventKind, object: &str, message: &str) {
        if self.fenced() {
            return;
        }
        self.log_op(|| StoreOp::Record {
            at,
            kind,
            object: object.to_string(),
            msg: message.to_string(),
        });
        self.push_event(at, kind, object, message);
    }

    fn push_event(&mut self, at: Time, kind: EventKind, object: &str, message: &str) {
        self.events.push(ClusterEvent { at, kind, object: object.to_string(), message: message.to_string() });
    }

    /// The bounded event log. Iterate it directly (`for ev in st.events()`)
    /// for the retained window, or read deltas with
    /// [`RingLog::since`] / [`ClusterStore::event_cursor`].
    pub fn events(&self) -> &RingLog<ClusterEvent> {
        &self.events
    }

    /// One past the newest event (the cursor a caught-up consumer stores).
    pub fn event_cursor(&self) -> usize {
        self.events.cursor()
    }

    /// Reconfigure the event log's retained window (the
    /// `control_plane.compaction_window` config knob).
    pub fn set_event_capacity(&mut self, capacity: usize) {
        if self.fenced() {
            return;
        }
        self.log_op(|| StoreOp::SetEventCapacity { capacity });
        self.events.set_capacity(capacity);
    }

    /// Debug/test hook: assert the free-capacity index exactly mirrors the
    /// free map. Returns the number of indexed (resource, node) entries.
    pub fn check_free_index(&self) -> usize {
        let mut count = 0;
        for (node, free) in &self.free {
            for (k, v) in free.iter() {
                assert!(
                    self.free_index
                        .get(k)
                        .map(|s| s.contains(&(v, node.clone())))
                        .unwrap_or(false),
                    "free index missing ({k}, {v}, {node})"
                );
                count += 1;
            }
        }
        let indexed: usize = self.free_index.values().map(|s| s.len()).sum();
        assert_eq!(indexed, count, "free index has stale entries");
        count
    }

    /// Aggregate resource usage: (used, allocatable) summed over nodes
    /// (restricted to physical nodes when `physical_only`).
    pub fn utilization(&self, physical_only: bool) -> (ResourceVec, ResourceVec) {
        let mut total = ResourceVec::new();
        let mut free = ResourceVec::new();
        for n in self.nodes.values() {
            if physical_only && n.virtual_node {
                continue;
            }
            total.add(&n.allocatable);
            if let Some(f) = self.free.get(&n.name) {
                free.add(f);
            }
        }
        let used = total.checked_sub(&free).unwrap_or_else(ResourceVec::new);
        (used, total)
    }
}

// --------------------------------------------------------------- durability

impl Enc for EventKind {
    fn enc(&self, b: &mut Vec<u8>) {
        let tag: u8 = match self {
            EventKind::PodCreated => 0,
            EventKind::PodScheduled => 1,
            EventKind::PodStarted => 2,
            EventKind::PodSucceeded => 3,
            EventKind::PodFailed => 4,
            EventKind::PodEvicted => 5,
            EventKind::PodUnschedulable => 6,
            EventKind::PodDeleted => 7,
            EventKind::NodeAdded => 8,
            EventKind::NodeRemoved => 9,
            EventKind::NodeModified => 10,
            EventKind::MigRepartitioned => 11,
        };
        b.push(tag);
    }
}

impl Dec for EventKind {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(match u8::dec(r)? {
            0 => EventKind::PodCreated,
            1 => EventKind::PodScheduled,
            2 => EventKind::PodStarted,
            3 => EventKind::PodSucceeded,
            4 => EventKind::PodFailed,
            5 => EventKind::PodEvicted,
            6 => EventKind::PodUnschedulable,
            7 => EventKind::PodDeleted,
            8 => EventKind::NodeAdded,
            9 => EventKind::NodeRemoved,
            10 => EventKind::NodeModified,
            11 => EventKind::MigRepartitioned,
            t => return Err(CodecError(format!("bad event kind tag {t}"))),
        })
    }
}

impl Enc for ClusterEvent {
    fn enc(&self, b: &mut Vec<u8>) {
        self.at.enc(b);
        self.kind.enc(b);
        self.object.enc(b);
        self.message.enc(b);
    }
}

impl Dec for ClusterEvent {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(ClusterEvent {
            at: Dec::dec(r)?,
            kind: Dec::dec(r)?,
            object: Dec::dec(r)?,
            message: Dec::dec(r)?,
        })
    }
}

impl Enc for PendingPod {
    fn enc(&self, b: &mut Vec<u8>) {
        self.priority.enc(b);
        self.name.enc(b);
    }
}

impl Dec for PendingPod {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(PendingPod { priority: Dec::dec(r)?, name: Dec::dec(r)? })
    }
}

/// Snapshots encode only *source* state (nodes, pods, pending queue, event
/// ring, resource version, ledger). The derived structures — the per-node
/// free vectors and the inverted free-capacity index — are rebuilt from
/// scratch on decode, so a snapshot can never smuggle a stale index past
/// a restore.
impl Enc for ClusterStore {
    fn enc(&self, b: &mut Vec<u8>) {
        self.nodes.enc(b);
        self.pods.enc(b);
        self.pending.enc(b);
        self.events.enc(b);
        self.resource_version.enc(b);
        self.ledger.enc(b);
    }
}

impl Dec for ClusterStore {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        let mut s = ClusterStore {
            nodes: Dec::dec(r)?,
            pods: Dec::dec(r)?,
            pending: Dec::dec(r)?,
            events: Dec::dec(r)?,
            resource_version: Dec::dec(r)?,
            ledger: Dec::dec(r)?,
            free: HashMap::new(),
            free_index: HashMap::new(),
            wal: None,
            writer_epoch: 0,
            fenced_below: 0,
            fenced_writes: 0,
        };
        let names: Vec<String> = s.nodes.keys().cloned().collect();
        for n in &names {
            s.recompute_free(n);
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::Payload;
    use crate::cluster::resources::{CPU, GPU};
    use crate::gpu::{GpuDevice, GpuModel};

    fn store_with_node() -> ClusterStore {
        let mut s = ClusterStore::new();
        let n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::TeslaT4)]);
        s.add_node(n, 0.0);
        s
    }

    fn pod(name: &str, cpu: i64, gpu: i64) -> PodSpec {
        let mut req = ResourceVec::cpu_millis(cpu);
        if gpu > 0 {
            req.set(GPU, gpu);
        }
        PodSpec::new(name, req, Payload::Sleep { duration: 5.0 })
    }

    fn pending_names(s: &ClusterStore) -> Vec<String> {
        s.pending_pods().map(str::to_string).collect()
    }

    #[test]
    fn bind_reserves_and_finish_releases() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 4000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 0);
        s.check_free_index();
        s.mark_running("p1", 2.1).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 7.0, "done").unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.pod("p1").unwrap().status.phase, PodPhase::Succeeded);
        s.check_free_index();
    }

    #[test]
    fn bind_rejects_overcommit() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.create_pod(pod("p2", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        let err = s.bind("p2", "n1", 2.0).unwrap_err();
        assert!(err.to_string().contains("insufficient"));
        // p2 still pending
        assert_eq!(pending_names(&s), vec!["p2".to_string()]);
    }

    #[test]
    fn evict_requeues_and_releases_capacity() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 0), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.mark_running("p1", 2.5).unwrap();
        s.evict_pod("p1", 3.0, true, "preempted by interactive").unwrap();
        let p = s.pod("p1").unwrap();
        assert_eq!(p.status.phase, PodPhase::Pending);
        assert_eq!(p.status.evictions, 1);
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert!(s.pending_pods().any(|n| n == "p1"));
        s.check_free_index();
    }

    #[test]
    fn duplicate_pod_name_panics() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.create_pod(pod("p1", 100, 0), 0.0);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn utilization_sums_nodes() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 3000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        let (used, total) = s.utilization(true);
        assert_eq!(used.get(CPU), 3000);
        assert_eq!(total.get(CPU), 6000);
    }

    #[test]
    fn delete_pod_releases_capacity_and_removes_record() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.delete_pod("p1", 3.0, "garbage collected").unwrap();
        assert!(s.pod("p1").is_none());
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::PodDeleted);
        assert!(s.delete_pod("p1", 4.0, "again").is_err(), "double delete errors");
        // deleting a pending pod drops it from the scheduling queue
        s.create_pod(pod("p2", 1000, 0), 5.0);
        s.delete_pod("p2", 6.0, "garbage collected").unwrap();
        assert_eq!(s.pending_count(), 0);
        s.check_free_index();
    }

    #[test]
    fn gc_removes_old_terminal_pods() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        s.mark_running("p1", 0.0).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 5.0, "ok").unwrap();
        assert_eq!(s.gc_finished(4.0), 0);
        assert_eq!(s.gc_finished(6.0), 1);
        assert!(s.pod("p1").is_none());
    }

    #[test]
    fn set_node_ready_records_only_real_changes() {
        let mut s = store_with_node();
        let before = s.events().len();
        assert!(s.set_node_ready("n1", true, 1.0, "noop"));
        assert_eq!(s.events().len(), before, "no event for a no-op flip");
        assert!(s.set_node_ready("n1", false, 2.0, "cordoned"));
        assert!(!s.node("n1").unwrap().ready);
        assert_eq!(s.events().len(), before + 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::NodeModified);
        assert!(!s.set_node_ready("ghost", false, 3.0, "x"));
    }

    #[test]
    fn recompute_free_after_allocatable_change() {
        let mut s = ClusterStore::new();
        let mut n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::A100_40GB)]);
        s.add_node(n.clone(), 0.0);
        s.create_pod(pod("p1", 1000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        // repartition the A100
        n.gpus[0]
            .repartition(crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap())
            .unwrap();
        n.refresh_extended_resources();
        *s.node_mut("n1").unwrap() = n;
        s.recompute_free("n1");
        let f = s.free_on("n1").unwrap();
        assert_eq!(f.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(f.get(CPU), 5000); // 6000 allocatable − 1000 reserved
        s.check_free_index();
    }

    #[test]
    fn pending_queue_keeps_priority_then_fifo_order() {
        let mut s = store_with_node();
        s.create_pod(pod("a-low", 100, 0).with_priority(0), 0.0);
        s.create_pod(pod("b-high", 100, 0).with_priority(100), 1.0);
        s.create_pod(pod("c-low", 100, 0).with_priority(0), 2.0);
        s.create_pod(pod("d-high", 100, 0).with_priority(100), 3.0);
        assert_eq!(pending_names(&s), vec!["b-high", "d-high", "a-low", "c-low"]);
        // an evicted requeue goes to the back of its priority class
        s.bind("b-high", "n1", 4.0).unwrap();
        s.evict_pod("b-high", 5.0, true, "requeue").unwrap();
        assert_eq!(pending_names(&s), vec!["d-high", "b-high", "a-low", "c-low"]);
    }

    #[test]
    fn free_index_prunes_candidates() {
        let mut s = store_with_node();
        let hits: Vec<&str> = s.nodes_with_free_at_least(GPU, 1).collect();
        assert_eq!(hits, vec!["n1"]);
        assert!(s.nodes_with_free_at_least(GPU, 2).next().is_none());
        assert!(s.nodes_with_free_at_least("xilinx.com/fpga-u250", 1).next().is_none());
        s.create_pod(pod("p1", 1000, 1), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        assert!(s.nodes_with_free_at_least(GPU, 1).next().is_none(), "GPU taken");
        assert_eq!(s.free_index_size(GPU), 0);
        s.finish_pod("p1", PodPhase::Succeeded, 1.0, "ok").unwrap();
        assert_eq!(s.nodes_with_free_at_least(GPU, 1).count(), 1);
    }

    #[test]
    fn repartition_refused_while_slices_bound() {
        let mut s = ClusterStore::new();
        let gpu = GpuDevice::partitioned(
            "g0",
            GpuModel::A100_40GB,
            crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap(),
        )
        .unwrap();
        s.add_node(Node::physical("n1", 32, 128 << 30, 1 << 40, vec![gpu]), 0.0);
        let req = ResourceVec::cpu_millis(500).with("nvidia.com/mig-1g.5gb", 1);
        s.create_pod(
            PodSpec::new("p1", req, Payload::Sleep { duration: 100.0 }),
            0.0,
        );
        s.bind("p1", "n1", 0.0).unwrap();
        // a slice is bound: collapsing back to a whole GPU must fail
        let whole = crate::gpu::MigLayout::new(GpuModel::A100_40GB, vec![]).unwrap();
        let err = s.repartition_gpu("n1", "g0", whole.clone(), 1.0).unwrap_err();
        assert!(err.to_string().contains("still bound"), "{err}");
        // the node still advertises the old layout, untouched
        assert_eq!(s.node("n1").unwrap().allocatable.get("nvidia.com/mig-1g.5gb"), 7);
        // release the slice: the same repartition now succeeds
        s.finish_pod("p1", PodPhase::Succeeded, 2.0, "done").unwrap();
        let (removed, added) = s.repartition_gpu("n1", "g0", whole, 3.0).unwrap();
        assert_eq!(removed.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(added.get(GPU), 1);
        let n = s.node("n1").unwrap();
        assert_eq!(n.allocatable.get("nvidia.com/mig-1g.5gb"), 0);
        assert_eq!(n.allocatable.get(GPU), 1);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::MigRepartitioned);
        s.check_free_index();
    }

    #[test]
    fn repartition_rejects_unknown_targets_and_bad_layouts() {
        let mut s = ClusterStore::new();
        s.add_node(
            Node::physical("n1", 8, 32 << 30, 1 << 40, vec![
                GpuDevice::whole("g0", GpuModel::A100_40GB),
                GpuDevice::whole("f0", GpuModel::AlveoU250),
            ]),
            0.0,
        );
        let seven = crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap();
        assert!(s.repartition_gpu("ghost", "g0", seven.clone(), 0.0).is_err());
        assert!(s.repartition_gpu("n1", "ghost", seven.clone(), 0.0).is_err());
        assert!(s.repartition_gpu("n1", "f0", seven.clone(), 0.0).is_err(), "FPGA refused");
        // A30 profiles on an A100 are invalid geometry
        let bad = crate::gpu::MigLayout {
            model: GpuModel::A100_40GB,
            instances: vec![crate::gpu::MigProfile::new(1, 6)],
        };
        assert!(s.repartition_gpu("n1", "g0", bad, 0.0).is_err());
        // and the valid one goes through, flipping whole → 7×1g
        s.repartition_gpu("n1", "g0", seven, 0.0).unwrap();
        assert_eq!(s.node("n1").unwrap().allocatable.get("nvidia.com/mig-1g.5gb"), 7);
    }

    #[test]
    fn event_log_compacts_within_capacity() {
        let mut s = store_with_node();
        s.set_event_capacity(8);
        for i in 0..40 {
            s.record(i as f64, EventKind::NodeModified, "n1", "flap");
        }
        assert_eq!(s.events().len(), 8);
        assert!(s.event_cursor() >= 40);
        assert!(s.events().since(0).is_err(), "stale cursor is Compacted");
        let tail: Vec<_> = s.events().since(s.event_cursor() - 2).unwrap().collect();
        assert_eq!(tail.len(), 2);
    }

    #[test]
    fn snapshot_roundtrip_rebuilds_derived_state() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.create_pod(pod("p2", 1000, 0).with_priority(50), 3.0);
        let bytes = s.to_bytes();
        let restored = ClusterStore::from_bytes(&bytes).unwrap();
        assert_eq!(restored.to_bytes(), bytes, "re-encode is byte-identical");
        assert_eq!(restored.resource_version(), s.resource_version());
        assert_eq!(restored.free_on("n1").unwrap().get(CPU), 4000);
        restored.check_free_index();
        assert_eq!(
            restored.pending_pods().collect::<Vec<_>>(),
            s.pending_pods().collect::<Vec<_>>()
        );
        assert_eq!(restored.events().len(), s.events().len());
        assert_eq!(restored.event_cursor(), s.event_cursor());
    }

    #[test]
    fn fence_rejects_stale_epoch_writes_without_logging() {
        use crate::cluster::wal::Wal;
        let wal = Wal::shared();
        let mut s = store_with_node();
        s.attach_wal(wal.clone());
        s.set_writer_epoch(1);
        s.create_pod(pod("p1", 1000, 0), 1.0);
        let rv = s.resource_version();
        let logged = wal.borrow().appended();
        // the fence goes up (promotion happened elsewhere); this writer
        // is now deposed
        s.set_fence(2);
        assert!(s.bind("p1", "n1", 2.0).is_err());
        s.create_pod(pod("p2", 1000, 0), 2.0);
        assert!(!s.set_node_ready("n1", false, 2.0, "cordon"));
        s.record(2.0, EventKind::PodUnschedulable, "p1", "x");
        assert_eq!(s.gc_finished(100.0), 0);
        // nothing changed, nothing was logged, every rejection counted
        assert_eq!(s.resource_version(), rv, "fenced writes must not touch state");
        assert!(s.pod("p2").is_none());
        assert_eq!(wal.borrow().appended(), logged, "fenced writes must not reach the wal");
        assert_eq!(s.fenced_writes(), 5);
        // restoring the epoch (a legitimate new leader) lifts the fence
        s.set_writer_epoch(2);
        s.bind("p1", "n1", 3.0).unwrap();
        assert_eq!(s.fenced_writes(), 5);
    }

    #[test]
    fn wal_replay_reproduces_store_state() {
        use crate::cluster::wal::{Wal, WalRecord};
        let wal = Wal::shared();
        let mut s = ClusterStore::new();
        s.attach_wal(wal.clone());
        let n = Node::physical(
            "n1",
            8,
            32 << 30,
            1 << 40,
            vec![GpuDevice::whole("g0", GpuModel::TeslaT4)],
        );
        s.add_node(n, 0.0);
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.create_pod(pod("p2", 9000, 0), 1.5);
        s.bind("p1", "n1", 2.0).unwrap();
        // a failed call is logged at entry too: replay reproduces its
        // resource-version bump and identical failure
        assert!(s.bind("p2", "n1", 2.5).is_err());
        s.mark_running("p1", 3.0).unwrap();
        s.record(3.5, EventKind::PodUnschedulable, "p2", "no fit");
        s.finish_pod("p1", PodPhase::Succeeded, 9.0, "done").unwrap();
        assert_eq!(s.gc_finished(10.0), 1);
        s.degrade_resource("n1", GPU, 1, 11.0);
        s.recover_resource("n1", GPU, 1, 12.0);

        let (records, warn) = wal.borrow().replay();
        assert!(warn.is_none(), "{warn:?}");
        let mut replayed = ClusterStore::new();
        for rec in records {
            match rec {
                WalRecord::Store(op) => replayed.apply_op(op),
                other => panic!("store-only log, got {other:?}"),
            }
        }
        s.detach_wal();
        assert_eq!(replayed.to_bytes(), s.to_bytes(), "replayed state byte-identical");
        assert_eq!(replayed.resource_version(), s.resource_version());
        replayed.check_free_index();
    }
}
