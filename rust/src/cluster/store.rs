//! The cluster state store: nodes + pods + events, with per-node free
//! capacity accounting and a resource-version counter (an etcd-lite).
//!
//! Single-writer semantics: controllers mutate the store through `&mut`
//! (the discrete-event engine is single-threaded), so no locking is needed
//! on the hot path — one of the reasons the scheduler sustains the §Perf
//! placement-rate target on one core.
//!
//! Three structures keep the read/schedule hot paths off full scans:
//!
//! * the **event log** is a bounded [`RingLog`] with absolute cursors —
//!   consumers (the API server's watch pump, the reconciler runtime) read
//!   only the suffix since their cursor and get a typed
//!   [`Compacted`](crate::util::ring::Compacted) error if they fell
//!   behind the retained window;
//! * the **pending queue** is kept in scheduling order (priority desc,
//!   FIFO within a class) at insert time, so the scheduler never rebuilds
//!   or clones the priority order per tick;
//! * the **free-capacity index** maps each resource to a sorted
//!   `(free amount, node)` set, updated incrementally on bind/release, so
//!   node selection iterates only nodes that can currently fit a request
//!   instead of every node in the cluster.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::cluster::node::Node;
use crate::cluster::pod::{Pod, PodPhase, PodSpec, PodStatus};
use crate::cluster::resources::ResourceVec;
use crate::gpu::mig::MigLayout;
use crate::gpu::GpuDevice;
use crate::monitoring::accounting::UsageLedger;
use crate::sim::clock::Time;
use crate::util::ring::RingLog;

/// Cluster event record (kubectl-events-like; feeds monitoring/accounting).
#[derive(Debug, Clone)]
pub struct ClusterEvent {
    pub at: Time,
    pub kind: EventKind,
    pub object: String,
    pub message: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    PodCreated,
    PodScheduled,
    PodStarted,
    PodSucceeded,
    PodFailed,
    PodEvicted,
    /// A pending pod could not be placed this pass (reason in the message);
    /// recorded once per (pod, reason) by the placement controller, not
    /// every tick.
    PodUnschedulable,
    /// The pod object was removed from the store entirely (garbage
    /// collection cascade) — distinct from a terminal phase transition.
    PodDeleted,
    NodeAdded,
    NodeRemoved,
    /// Node state changed in place: cordoned/uncordoned, allocatable
    /// degraded or restored (chaos GPU faults), readiness flips.
    NodeModified,
    MigRepartitioned,
}

/// One pending-queue entry. The queue is kept sorted (priority desc, FIFO
/// within a class) so scheduling passes read it in order without sorting.
#[derive(Debug, Clone)]
pub(crate) struct PendingPod {
    pub(crate) priority: i32,
    pub(crate) name: String,
}

/// The store.
#[derive(Debug, Default)]
pub struct ClusterStore {
    nodes: BTreeMap<String, Node>,
    /// Free = allocatable − sum(requests of pods assigned & not terminal).
    free: HashMap<String, ResourceVec>,
    pods: HashMap<String, Pod>,
    /// Pending queue in scheduling order: priority desc, then FIFO.
    pending: Vec<PendingPod>,
    /// Bounded event log (ring with absolute cursors).
    events: RingLog<ClusterEvent>,
    resource_version: u64,
    /// resource → sorted (free amount, node) pairs with amount > 0; the
    /// scheduler's feasibility pruning. Maintained incrementally wherever
    /// `free` changes.
    free_index: HashMap<String, BTreeSet<(i64, String)>>,
    /// Persistent per-principal usage, accrued at every terminal-phase
    /// transition — the accounting source of truth that survives pod GC.
    ledger: UsageLedger,
}

/// Apply a free-vector change to the inverted capacity index: for every
/// resource whose amount changed, drop the stale `(amount, node)` entry
/// and insert the new one (zero amounts are not indexed).
fn index_update(
    idx: &mut HashMap<String, BTreeSet<(i64, String)>>,
    node: &str,
    old: &ResourceVec,
    new: &ResourceVec,
) {
    for (k, v) in old.iter() {
        let nv = new.get(k);
        if nv != v {
            if let Some(set) = idx.get_mut(k) {
                set.remove(&(v, node.to_string()));
            }
            if nv > 0 {
                idx.entry(k.to_string()).or_default().insert((nv, node.to_string()));
            }
        }
    }
    for (k, v) in new.iter() {
        if old.get(k) == 0 {
            idx.entry(k.to_string()).or_default().insert((v, node.to_string()));
        }
    }
}

impl ClusterStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self) -> u64 {
        self.resource_version += 1;
        self.resource_version
    }

    pub fn resource_version(&self) -> u64 {
        self.resource_version
    }

    // ------------------------------------------------------------- nodes

    pub fn add_node(&mut self, node: Node, at: Time) {
        self.bump();
        let old = self.free.get(&node.name).cloned().unwrap_or_default();
        index_update(&mut self.free_index, &node.name, &old, &node.allocatable);
        self.free.insert(node.name.clone(), node.allocatable.clone());
        self.record(at, EventKind::NodeAdded, &node.name.clone(), "node registered");
        self.nodes.insert(node.name.clone(), node);
    }

    pub fn remove_node(&mut self, name: &str, at: Time) -> Option<Node> {
        self.bump();
        if let Some(old) = self.free.remove(name) {
            index_update(&mut self.free_index, name, &old, &ResourceVec::new());
        }
        let n = self.nodes.remove(name);
        if n.is_some() {
            self.record(at, EventKind::NodeRemoved, name, "node removed");
        }
        n
    }

    pub fn node(&self, name: &str) -> Option<&Node> {
        self.nodes.get(name)
    }

    pub fn node_mut(&mut self, name: &str) -> Option<&mut Node> {
        self.bump();
        self.nodes.get_mut(name)
    }

    /// Flip a node's readiness (cordon/uncordon). Records a `NodeModified`
    /// event when the state actually changes; returns false for unknown
    /// nodes.
    pub fn set_node_ready(&mut self, name: &str, ready: bool, at: Time, msg: &str) -> bool {
        let changed = match self.nodes.get_mut(name) {
            None => return false,
            Some(n) => {
                if n.ready == ready {
                    false
                } else {
                    n.ready = ready;
                    true
                }
            }
        };
        if changed {
            self.bump();
            self.record(at, EventKind::NodeModified, name, msg);
        }
        true
    }

    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.values()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Free (unreserved) capacity on a node.
    pub fn free_on(&self, node: &str) -> Option<&ResourceVec> {
        self.free.get(node)
    }

    /// Names of nodes with at least `qty` free units of `resource`
    /// (ascending free amount; the scheduler sorts candidates by name).
    pub fn nodes_with_free_at_least(
        &self,
        resource: &str,
        qty: i64,
    ) -> impl Iterator<Item = &str> {
        self.free_index
            .get(resource)
            .into_iter()
            .flat_map(move |set| set.range((qty, String::new())..).map(|(_, n)| n.as_str()))
    }

    /// How many nodes currently have any free capacity of `resource`
    /// (index selectivity hint for the scheduler).
    pub fn free_index_size(&self, resource: &str) -> usize {
        self.free_index.get(resource).map(|s| s.len()).unwrap_or(0)
    }

    /// Every installed accelerator with its hosting node, in (node, slot)
    /// order — deterministic because the node map is sorted by name.
    pub fn gpu_devices(&self) -> impl Iterator<Item = (&Node, &GpuDevice)> {
        self.nodes.values().flat_map(|n| n.gpus.iter().map(move |g| (n, g)))
    }

    /// Find a device by id across all nodes.
    pub fn find_gpu(&self, device_id: &str) -> Option<(&Node, &GpuDevice)> {
        self.gpu_devices().find(|(_, g)| g.id == device_id)
    }

    /// Safely apply a new MIG `layout` to device `device_id` on
    /// `node_name` — the only repartition path on a device installed in a
    /// node. Refuses while any of the capacity the device would stop
    /// advertising is still bound by live pods, then swaps the layout,
    /// re-derives the node's extended resources, recomputes free capacity
    /// (maintaining the per-resource free index), and records a
    /// `MigRepartitioned` event for the device plus a `NodeModified` event
    /// for the node. Returns the `(removed, added)` extended-resource
    /// advertisements so callers can rebalance queue quotas.
    pub fn repartition_gpu(
        &mut self,
        node_name: &str,
        device_id: &str,
        layout: MigLayout,
        at: Time,
    ) -> anyhow::Result<(ResourceVec, ResourceVec)> {
        let node = self
            .nodes
            .get(node_name)
            .ok_or_else(|| anyhow::anyhow!("no node {node_name}"))?;
        let idx = node
            .gpus
            .iter()
            .position(|g| g.id == device_id)
            .ok_or_else(|| anyhow::anyhow!("no device {device_id} on node {node_name}"))?;
        let model = node.gpus[idx].model;
        anyhow::ensure!(!model.is_fpga(), "device {device_id} is an FPGA, not repartitionable");
        let validated = MigLayout::new(model, layout.instances)
            .map_err(|e| anyhow::anyhow!("invalid layout for {device_id}: {e}"))?;
        let old_adv = node.gpus[idx].extended_resources();
        let new_adv = validated.extended_resources();
        // the bound-slices guard: for every resource whose advertisement
        // shrinks, the removed amount must be sitting free on the node —
        // otherwise live pods hold slices of the old layout and swapping
        // it would leak their reserved capacity
        let free = self.free.get(node_name).cloned().unwrap_or_default();
        for (k, v) in old_adv.iter() {
            let shrink = v - new_adv.get(k);
            if shrink > 0 && free.get(k) < shrink {
                anyhow::bail!(
                    "repartition refused: {k} on {device_id} still bound \
                     (free {} < removed {shrink})",
                    free.get(k)
                );
            }
        }
        let label = if validated.enabled() {
            validated.instances.iter().map(|p| p.label()).collect::<Vec<_>>().join("+")
        } else {
            "whole".to_string()
        };
        self.bump();
        let node = self.nodes.get_mut(node_name).unwrap();
        node.gpus[idx].repartition(validated).expect("layout pre-validated");
        node.refresh_extended_resources();
        self.recompute_free(node_name);
        self.record(
            at,
            EventKind::NodeModified,
            node_name,
            &format!("mig repartitioned: {device_id} -> {label}"),
        );
        self.record(at, EventKind::MigRepartitioned, device_id, &format!("{node_name}: {label}"));
        Ok((old_adv, new_adv))
    }

    /// Recompute a node's free vector after its allocatable changed
    /// (MIG repartition): free = new allocatable − requests of live pods.
    pub fn recompute_free(&mut self, node_name: &str) {
        let Some(node) = self.nodes.get(node_name) else { return };
        let mut free = node.allocatable.clone();
        for p in self.pods.values() {
            if p.status.node.as_deref() == Some(node_name)
                && matches!(p.status.phase, PodPhase::Scheduled | PodPhase::Running)
            {
                free = free.checked_sub(&p.spec.requests).unwrap_or_else(ResourceVec::new);
            }
        }
        let old = self.free.get(node_name).cloned().unwrap_or_default();
        index_update(&mut self.free_index, node_name, &old, &free);
        self.free.insert(node_name.to_string(), free);
    }

    // -------------------------------------------------------------- pods

    /// Insert into the pending queue in scheduling order: after every
    /// entry of equal-or-higher priority (priority desc, FIFO within a
    /// class — requeued pods go to the back of their class).
    fn enqueue_pending(&mut self, priority: i32, name: String) {
        let pos = self.pending.partition_point(|e| e.priority >= priority);
        self.pending.insert(pos, PendingPod { priority, name });
    }

    /// Create a pod in Pending and enqueue it for scheduling.
    pub fn create_pod(&mut self, spec: PodSpec, at: Time) -> String {
        self.bump();
        let name = spec.name.clone();
        assert!(
            !self.pods.contains_key(&name),
            "duplicate pod name {name}"
        );
        self.record(at, EventKind::PodCreated, &name, "created");
        let priority = spec.priority;
        self.pods.insert(name.clone(), Pod { spec, status: PodStatus::new(at) });
        self.enqueue_pending(priority, name.clone());
        name
    }

    pub fn pod(&self, name: &str) -> Option<&Pod> {
        self.pods.get(name)
    }

    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    /// Pending pod names in scheduling order (priority desc, FIFO within a
    /// class).
    pub fn pending_pods(&self) -> impl Iterator<Item = &str> {
        self.pending.iter().map(|e| e.name.as_str())
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// Detach the pending queue for a scheduling pass (the scheduler walks
    /// it while binding against `&mut self`, without cloning every name).
    /// Unplaced entries must be handed back via [`restore_pending`].
    pub(crate) fn take_pending(&mut self) -> Vec<PendingPod> {
        std::mem::take(&mut self.pending)
    }

    /// Hand back the unplaced suffix of a detached pending queue. Entries
    /// are already in scheduling order and *predate* anything enqueued
    /// while the queue was detached, so they merge in **before** any
    /// equal-priority newcomer (FIFO within a class is preserved).
    pub(crate) fn restore_pending(&mut self, entries: Vec<PendingPod>) {
        if self.pending.is_empty() {
            self.pending = entries;
            return;
        }
        let newcomers = std::mem::replace(&mut self.pending, entries);
        for e in newcomers {
            // enqueue_pending places after every >=-priority entry —
            // i.e. behind the restored (older) members of its class
            self.enqueue_pending(e.priority, e.name);
        }
    }

    /// Bind a pending pod to a node (scheduler decision). Reserves capacity.
    pub fn bind(&mut self, pod_name: &str, node_name: &str, at: Time) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        let free = self
            .free
            .get_mut(node_name)
            .ok_or_else(|| anyhow::anyhow!("no node {node_name}"))?;
        let rem = free
            .checked_sub(&pod.spec.requests)
            .ok_or_else(|| anyhow::anyhow!("insufficient free capacity on {node_name}"))?;
        index_update(&mut self.free_index, node_name, free, &rem);
        *free = rem;
        pod.status.phase = PodPhase::Scheduled;
        pod.status.node = Some(node_name.to_string());
        pod.status.scheduled_at = Some(at);
        self.pending.retain(|e| e.name != pod_name);
        self.record(at, EventKind::PodScheduled, pod_name, node_name);
        Ok(())
    }

    /// Transition Scheduled → Running.
    pub fn mark_running(&mut self, pod_name: &str, at: Time) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Scheduled, "pod {pod_name} not scheduled");
        pod.status.phase = PodPhase::Running;
        pod.status.started_at = Some(at);
        self.record(at, EventKind::PodStarted, pod_name, "started");
        Ok(())
    }

    /// Terminal transition; releases node capacity.
    pub fn finish_pod(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        anyhow::ensure!(phase.is_terminal(), "finish_pod needs terminal phase");
        self.release(pod_name, phase, at, msg)
    }

    /// Evict a running/scheduled pod (releases capacity, back to Pending if
    /// requeue=true, else marked Evicted permanently).
    pub fn evict_pod(&mut self, pod_name: &str, at: Time, requeue: bool, msg: &str) -> anyhow::Result<()> {
        self.release(pod_name, PodPhase::Evicted, at, msg)?;
        if requeue {
            let pod = self.pods.get_mut(pod_name).unwrap();
            pod.status.phase = PodPhase::Pending;
            pod.status.node = None;
            pod.status.scheduled_at = None;
            pod.status.started_at = None;
            pod.status.evictions += 1;
            let priority = pod.spec.priority;
            self.enqueue_pending(priority, pod_name.to_string());
        }
        Ok(())
    }

    /// Cancel a pod that is still Pending (holds no capacity): removes it
    /// from the scheduling queue and marks it Evicted.
    pub fn cancel_pending(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(pod.status.phase == PodPhase::Pending, "pod {pod_name} not pending");
        pod.status.phase = PodPhase::Evicted;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        self.pending.retain(|e| e.name != pod_name);
        self.record(at, EventKind::PodEvicted, pod_name, msg);
        Ok(())
    }

    fn release(&mut self, pod_name: &str, phase: PodPhase, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get_mut(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        anyhow::ensure!(
            matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running),
            "pod {pod_name} not live (phase {:?})",
            pod.status.phase
        );
        if let Some(node) = pod.status.node.clone() {
            if let Some(free) = self.free.get_mut(&node) {
                let old = free.clone();
                free.add(&pod.spec.requests);
                index_update(&mut self.free_index, &node, &old, free);
            }
        }
        // accrue the run interval into the persistent accounting ledger at
        // the terminal transition — the record survives GC of the pod
        // object, and a zero-hour (same-tick) interval still counts the pod
        if let Some(start) = pod.status.started_at {
            let hours = ((at - start).max(0.0)) / 3600.0;
            let node = pod.status.node.as_deref().and_then(|n| self.nodes.get(n));
            self.ledger.accrue(
                &pod.spec.user,
                &pod.spec.project,
                &pod.spec.requests,
                node,
                hours,
                !pod.status.accounted,
            );
            pod.status.accounted = true;
        }
        pod.status.phase = phase;
        pod.status.finished_at = Some(at);
        pod.status.message = msg.to_string();
        let kind = match phase {
            PodPhase::Succeeded => EventKind::PodSucceeded,
            PodPhase::Failed => EventKind::PodFailed,
            PodPhase::Evicted => EventKind::PodEvicted,
            _ => unreachable!(),
        };
        self.record(at, kind, pod_name, msg);
        Ok(())
    }

    /// Remove a pod object entirely (the ownerReferences GC cascade).
    /// Releases reserved capacity if the pod was live, drops it from the
    /// pending queue, and records a `PodDeleted` event.
    pub fn delete_pod(&mut self, pod_name: &str, at: Time, msg: &str) -> anyhow::Result<()> {
        self.bump();
        let pod = self
            .pods
            .get(pod_name)
            .ok_or_else(|| anyhow::anyhow!("no pod {pod_name}"))?;
        if matches!(pod.status.phase, PodPhase::Scheduled | PodPhase::Running) {
            if let Some(node) = pod.status.node.clone() {
                if let Some(free) = self.free.get_mut(&node) {
                    let old = free.clone();
                    free.add(&pod.spec.requests);
                    index_update(&mut self.free_index, &node, &old, free);
                }
            }
            // a live pod deleted by the GC cascade still ran: accrue its
            // interval before the object disappears
            if let Some(start) = pod.status.started_at {
                let hours = ((at - start).max(0.0)) / 3600.0;
                let node = pod.status.node.as_deref().and_then(|n| self.nodes.get(n));
                self.ledger.accrue(
                    &pod.spec.user,
                    &pod.spec.project,
                    &pod.spec.requests,
                    node,
                    hours,
                    !pod.status.accounted,
                );
            }
        }
        self.pods.remove(pod_name);
        self.pending.retain(|e| e.name != pod_name);
        self.record(at, EventKind::PodDeleted, pod_name, msg);
        Ok(())
    }

    /// Remove terminal pods older than `before` (GC).
    pub fn gc_finished(&mut self, before: Time) -> usize {
        let victims: Vec<String> = self
            .pods
            .iter()
            .filter(|(_, p)| {
                p.status.phase.is_terminal()
                    && p.status.finished_at.map(|t| t < before).unwrap_or(false)
            })
            .map(|(n, _)| n.clone())
            .collect();
        for v in &victims {
            self.pods.remove(v);
        }
        victims.len()
    }

    // ------------------------------------------------------------ ledger

    /// The persistent accounting ledger: usage accrued at terminal-phase
    /// transitions (finish/evict/delete-while-live), surviving pod GC.
    pub fn usage_ledger(&self) -> &UsageLedger {
        &self.ledger
    }

    // ------------------------------------------------------------ events

    pub fn record(&mut self, at: Time, kind: EventKind, object: &str, message: &str) {
        self.events.push(ClusterEvent { at, kind, object: object.to_string(), message: message.to_string() });
    }

    /// The bounded event log. Iterate it directly (`for ev in st.events()`)
    /// for the retained window, or read deltas with
    /// [`RingLog::since`] / [`ClusterStore::event_cursor`].
    pub fn events(&self) -> &RingLog<ClusterEvent> {
        &self.events
    }

    /// One past the newest event (the cursor a caught-up consumer stores).
    pub fn event_cursor(&self) -> usize {
        self.events.cursor()
    }

    /// Reconfigure the event log's retained window (the
    /// `control_plane.compaction_window` config knob).
    pub fn set_event_capacity(&mut self, capacity: usize) {
        self.events.set_capacity(capacity);
    }

    /// Debug/test hook: assert the free-capacity index exactly mirrors the
    /// free map. Returns the number of indexed (resource, node) entries.
    pub fn check_free_index(&self) -> usize {
        let mut count = 0;
        for (node, free) in &self.free {
            for (k, v) in free.iter() {
                assert!(
                    self.free_index
                        .get(k)
                        .map(|s| s.contains(&(v, node.clone())))
                        .unwrap_or(false),
                    "free index missing ({k}, {v}, {node})"
                );
                count += 1;
            }
        }
        let indexed: usize = self.free_index.values().map(|s| s.len()).sum();
        assert_eq!(indexed, count, "free index has stale entries");
        count
    }

    /// Aggregate resource usage: (used, allocatable) summed over nodes
    /// (restricted to physical nodes when `physical_only`).
    pub fn utilization(&self, physical_only: bool) -> (ResourceVec, ResourceVec) {
        let mut total = ResourceVec::new();
        let mut free = ResourceVec::new();
        for n in self.nodes.values() {
            if physical_only && n.virtual_node {
                continue;
            }
            total.add(&n.allocatable);
            if let Some(f) = self.free.get(&n.name) {
                free.add(f);
            }
        }
        let used = total.checked_sub(&free).unwrap_or_else(ResourceVec::new);
        (used, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::Payload;
    use crate::cluster::resources::{CPU, GPU};
    use crate::gpu::{GpuDevice, GpuModel};

    fn store_with_node() -> ClusterStore {
        let mut s = ClusterStore::new();
        let n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::TeslaT4)]);
        s.add_node(n, 0.0);
        s
    }

    fn pod(name: &str, cpu: i64, gpu: i64) -> PodSpec {
        let mut req = ResourceVec::cpu_millis(cpu);
        if gpu > 0 {
            req.set(GPU, gpu);
        }
        PodSpec::new(name, req, Payload::Sleep { duration: 5.0 })
    }

    fn pending_names(s: &ClusterStore) -> Vec<String> {
        s.pending_pods().map(str::to_string).collect()
    }

    #[test]
    fn bind_reserves_and_finish_releases() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 4000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 0);
        s.check_free_index();
        s.mark_running("p1", 2.1).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 7.0, "done").unwrap();
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.pod("p1").unwrap().status.phase, PodPhase::Succeeded);
        s.check_free_index();
    }

    #[test]
    fn bind_rejects_overcommit() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.create_pod(pod("p2", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        let err = s.bind("p2", "n1", 2.0).unwrap_err();
        assert!(err.to_string().contains("insufficient"));
        // p2 still pending
        assert_eq!(pending_names(&s), vec!["p2".to_string()]);
    }

    #[test]
    fn evict_requeues_and_releases_capacity() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 0), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.mark_running("p1", 2.5).unwrap();
        s.evict_pod("p1", 3.0, true, "preempted by interactive").unwrap();
        let p = s.pod("p1").unwrap();
        assert_eq!(p.status.phase, PodPhase::Pending);
        assert_eq!(p.status.evictions, 1);
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert!(s.pending_pods().any(|n| n == "p1"));
        s.check_free_index();
    }

    #[test]
    fn duplicate_pod_name_panics() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.create_pod(pod("p1", 100, 0), 0.0);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn utilization_sums_nodes() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 3000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        let (used, total) = s.utilization(true);
        assert_eq!(used.get(CPU), 3000);
        assert_eq!(total.get(CPU), 6000);
    }

    #[test]
    fn delete_pod_releases_capacity_and_removes_record() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 2000, 1), 1.0);
        s.bind("p1", "n1", 2.0).unwrap();
        s.delete_pod("p1", 3.0, "garbage collected").unwrap();
        assert!(s.pod("p1").is_none());
        assert_eq!(s.free_on("n1").unwrap().get(CPU), 6000);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::PodDeleted);
        assert!(s.delete_pod("p1", 4.0, "again").is_err(), "double delete errors");
        // deleting a pending pod drops it from the scheduling queue
        s.create_pod(pod("p2", 1000, 0), 5.0);
        s.delete_pod("p2", 6.0, "garbage collected").unwrap();
        assert_eq!(s.pending_count(), 0);
        s.check_free_index();
    }

    #[test]
    fn gc_removes_old_terminal_pods() {
        let mut s = store_with_node();
        s.create_pod(pod("p1", 100, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        s.mark_running("p1", 0.0).unwrap();
        s.finish_pod("p1", PodPhase::Succeeded, 5.0, "ok").unwrap();
        assert_eq!(s.gc_finished(4.0), 0);
        assert_eq!(s.gc_finished(6.0), 1);
        assert!(s.pod("p1").is_none());
    }

    #[test]
    fn set_node_ready_records_only_real_changes() {
        let mut s = store_with_node();
        let before = s.events().len();
        assert!(s.set_node_ready("n1", true, 1.0, "noop"));
        assert_eq!(s.events().len(), before, "no event for a no-op flip");
        assert!(s.set_node_ready("n1", false, 2.0, "cordoned"));
        assert!(!s.node("n1").unwrap().ready);
        assert_eq!(s.events().len(), before + 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::NodeModified);
        assert!(!s.set_node_ready("ghost", false, 3.0, "x"));
    }

    #[test]
    fn recompute_free_after_allocatable_change() {
        let mut s = ClusterStore::new();
        let mut n = Node::physical("n1", 8, 32 << 30, 1 << 40, vec![GpuDevice::whole("g0", GpuModel::A100_40GB)]);
        s.add_node(n.clone(), 0.0);
        s.create_pod(pod("p1", 1000, 0), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        // repartition the A100
        n.gpus[0]
            .repartition(crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap())
            .unwrap();
        n.refresh_extended_resources();
        *s.node_mut("n1").unwrap() = n;
        s.recompute_free("n1");
        let f = s.free_on("n1").unwrap();
        assert_eq!(f.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(f.get(CPU), 5000); // 6000 allocatable − 1000 reserved
        s.check_free_index();
    }

    #[test]
    fn pending_queue_keeps_priority_then_fifo_order() {
        let mut s = store_with_node();
        s.create_pod(pod("a-low", 100, 0).with_priority(0), 0.0);
        s.create_pod(pod("b-high", 100, 0).with_priority(100), 1.0);
        s.create_pod(pod("c-low", 100, 0).with_priority(0), 2.0);
        s.create_pod(pod("d-high", 100, 0).with_priority(100), 3.0);
        assert_eq!(pending_names(&s), vec!["b-high", "d-high", "a-low", "c-low"]);
        // an evicted requeue goes to the back of its priority class
        s.bind("b-high", "n1", 4.0).unwrap();
        s.evict_pod("b-high", 5.0, true, "requeue").unwrap();
        assert_eq!(pending_names(&s), vec!["d-high", "b-high", "a-low", "c-low"]);
    }

    #[test]
    fn free_index_prunes_candidates() {
        let mut s = store_with_node();
        let hits: Vec<&str> = s.nodes_with_free_at_least(GPU, 1).collect();
        assert_eq!(hits, vec!["n1"]);
        assert!(s.nodes_with_free_at_least(GPU, 2).next().is_none());
        assert!(s.nodes_with_free_at_least("xilinx.com/fpga-u250", 1).next().is_none());
        s.create_pod(pod("p1", 1000, 1), 0.0);
        s.bind("p1", "n1", 0.0).unwrap();
        assert!(s.nodes_with_free_at_least(GPU, 1).next().is_none(), "GPU taken");
        assert_eq!(s.free_index_size(GPU), 0);
        s.finish_pod("p1", PodPhase::Succeeded, 1.0, "ok").unwrap();
        assert_eq!(s.nodes_with_free_at_least(GPU, 1).count(), 1);
    }

    #[test]
    fn repartition_refused_while_slices_bound() {
        let mut s = ClusterStore::new();
        let gpu = GpuDevice::partitioned(
            "g0",
            GpuModel::A100_40GB,
            crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap(),
        )
        .unwrap();
        s.add_node(Node::physical("n1", 32, 128 << 30, 1 << 40, vec![gpu]), 0.0);
        let req = ResourceVec::cpu_millis(500).with("nvidia.com/mig-1g.5gb", 1);
        s.create_pod(
            PodSpec::new("p1", req, Payload::Sleep { duration: 100.0 }),
            0.0,
        );
        s.bind("p1", "n1", 0.0).unwrap();
        // a slice is bound: collapsing back to a whole GPU must fail
        let whole = crate::gpu::MigLayout::new(GpuModel::A100_40GB, vec![]).unwrap();
        let err = s.repartition_gpu("n1", "g0", whole.clone(), 1.0).unwrap_err();
        assert!(err.to_string().contains("still bound"), "{err}");
        // the node still advertises the old layout, untouched
        assert_eq!(s.node("n1").unwrap().allocatable.get("nvidia.com/mig-1g.5gb"), 7);
        // release the slice: the same repartition now succeeds
        s.finish_pod("p1", PodPhase::Succeeded, 2.0, "done").unwrap();
        let (removed, added) = s.repartition_gpu("n1", "g0", whole, 3.0).unwrap();
        assert_eq!(removed.get("nvidia.com/mig-1g.5gb"), 7);
        assert_eq!(added.get(GPU), 1);
        let n = s.node("n1").unwrap();
        assert_eq!(n.allocatable.get("nvidia.com/mig-1g.5gb"), 0);
        assert_eq!(n.allocatable.get(GPU), 1);
        assert_eq!(s.free_on("n1").unwrap().get(GPU), 1);
        assert_eq!(s.events().last().unwrap().kind, EventKind::MigRepartitioned);
        s.check_free_index();
    }

    #[test]
    fn repartition_rejects_unknown_targets_and_bad_layouts() {
        let mut s = ClusterStore::new();
        s.add_node(
            Node::physical("n1", 8, 32 << 30, 1 << 40, vec![
                GpuDevice::whole("g0", GpuModel::A100_40GB),
                GpuDevice::whole("f0", GpuModel::AlveoU250),
            ]),
            0.0,
        );
        let seven = crate::gpu::MigLayout::max_sharing(GpuModel::A100_40GB).unwrap();
        assert!(s.repartition_gpu("ghost", "g0", seven.clone(), 0.0).is_err());
        assert!(s.repartition_gpu("n1", "ghost", seven.clone(), 0.0).is_err());
        assert!(s.repartition_gpu("n1", "f0", seven.clone(), 0.0).is_err(), "FPGA refused");
        // A30 profiles on an A100 are invalid geometry
        let bad = crate::gpu::MigLayout {
            model: GpuModel::A100_40GB,
            instances: vec![crate::gpu::MigProfile::new(1, 6)],
        };
        assert!(s.repartition_gpu("n1", "g0", bad, 0.0).is_err());
        // and the valid one goes through, flipping whole → 7×1g
        s.repartition_gpu("n1", "g0", seven, 0.0).unwrap();
        assert_eq!(s.node("n1").unwrap().allocatable.get("nvidia.com/mig-1g.5gb"), 7);
    }

    #[test]
    fn event_log_compacts_within_capacity() {
        let mut s = store_with_node();
        s.set_event_capacity(8);
        for i in 0..40 {
            s.record(i as f64, EventKind::NodeModified, "n1", "flap");
        }
        assert_eq!(s.events().len(), 8);
        assert!(s.event_cursor() >= 40);
        assert!(s.events().since(0).is_err(), "stale cursor is Compacted");
        let tail: Vec<_> = s.events().since(s.event_cursor() - 2).unwrap().collect();
        assert_eq!(tail.len(), 2);
    }
}
