//! Kubernetes-style resource quantities and arithmetic.
//!
//! Resources are named counters: `cpu` (millicores), `memory` (bytes),
//! `ephemeral-storage` (bytes), plus *extended resources* advertised by
//! device plugins — whole GPUs (`nvidia.com/gpu`), MIG slices
//! (`nvidia.com/mig-1g.5gb`, ...), and FPGA boards (`xilinx.com/fpga-u250`).
//! This mirrors how the real platform's GPU Operator exposes MIG devices.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::codec::{CodecError, Dec, Enc, Reader};

/// Canonical resource names.
pub const CPU: &str = "cpu"; // millicores
pub const MEMORY: &str = "memory"; // bytes
pub const STORAGE: &str = "ephemeral-storage"; // bytes
pub const GPU: &str = "nvidia.com/gpu"; // whole GPUs

/// Extended-resource name for a MIG profile, e.g. `nvidia.com/mig-1g.5gb`.
pub fn mig_resource(compute_slices: u8, mem_gb: u16) -> String {
    format!("nvidia.com/mig-{compute_slices}g.{mem_gb}gb")
}

/// FPGA extended-resource name, e.g. `xilinx.com/fpga-u250`.
pub fn fpga_resource(board: &str) -> String {
    format!("xilinx.com/fpga-{}", board.to_lowercase())
}

/// A bag of named resource quantities. Values are non-negative.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ResourceVec(BTreeMap<String, i64>);

impl ResourceVec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style insert.
    pub fn with(mut self, name: &str, qty: i64) -> Self {
        self.set(name, qty);
        self
    }

    pub fn cpu_millis(qty: i64) -> Self {
        Self::new().with(CPU, qty)
    }

    pub fn set(&mut self, name: &str, qty: i64) {
        assert!(qty >= 0, "resource {name} quantity must be >= 0, got {qty}");
        if qty == 0 {
            self.0.remove(name);
        } else {
            self.0.insert(name.to_string(), qty);
        }
    }

    pub fn get(&self, name: &str) -> i64 {
        self.0.get(name).copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, i64)> {
        self.0.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// True if `self` (a request) fits within `avail`.
    pub fn fits_in(&self, avail: &ResourceVec) -> bool {
        self.iter().all(|(k, v)| v <= avail.get(k))
    }

    /// self += other
    pub fn add(&mut self, other: &ResourceVec) {
        for (k, v) in other.iter() {
            let cur = self.get(k);
            self.set(k, cur + v);
        }
    }

    /// self -= other; panics (debug) / clamps (release) on underflow — an
    /// underflow means double-free of capacity, callers must check first.
    pub fn sub(&mut self, other: &ResourceVec) {
        for (k, v) in other.iter() {
            let cur = self.get(k);
            debug_assert!(cur >= v, "resource underflow on {k}: {cur} - {v}");
            self.set(k, (cur - v).max(0));
        }
    }

    /// Checked subtraction: None if it would underflow.
    pub fn checked_sub(&self, other: &ResourceVec) -> Option<ResourceVec> {
        if other.fits_in(self) {
            let mut r = self.clone();
            r.sub(other);
            Some(r)
        } else {
            None
        }
    }

    pub fn plus(&self, other: &ResourceVec) -> ResourceVec {
        let mut r = self.clone();
        r.add(other);
        r
    }

    /// Fraction of `capacity` consumed, per resource, as the max across
    /// resources present in capacity (scheduler scoring).
    pub fn dominant_share(&self, capacity: &ResourceVec) -> f64 {
        let mut share: f64 = 0.0;
        for (k, cap) in capacity.iter() {
            if cap > 0 {
                share = share.max(self.get(k) as f64 / cap as f64);
            }
        }
        share
    }

    /// Scale all quantities by an integer factor (pod replicas).
    pub fn scaled(&self, n: i64) -> ResourceVec {
        let mut r = ResourceVec::new();
        for (k, v) in self.iter() {
            r.set(k, v * n);
        }
        r
    }
}

impl Enc for ResourceVec {
    fn enc(&self, b: &mut Vec<u8>) {
        self.0.enc(b);
    }
}

impl Dec for ResourceVec {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        let m: BTreeMap<String, i64> = Dec::dec(r)?;
        // re-establish the type's invariants (non-negative, zeros pruned)
        // instead of trusting the wire
        for (k, v) in &m {
            if *v <= 0 {
                return Err(CodecError(format!("resource {k} has non-positive quantity {v}")));
            }
        }
        Ok(ResourceVec(m))
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (k, v) in self.iter() {
            if !first {
                write!(f, ", ")?;
            }
            first = false;
            match k {
                CPU => write!(f, "cpu={}m", v)?,
                MEMORY | STORAGE => write!(f, "{k}={}", crate::util::fmt_bytes(v as u64))?,
                _ => write!(f, "{k}={v}")?,
            }
        }
        if first {
            write!(f, "∅")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rv(pairs: &[(&str, i64)]) -> ResourceVec {
        let mut r = ResourceVec::new();
        for (k, v) in pairs {
            r.set(k, *v);
        }
        r
    }

    #[test]
    fn fits_and_arithmetic() {
        let avail = rv(&[(CPU, 4000), (MEMORY, 8 << 30), (GPU, 2)]);
        let req = rv(&[(CPU, 1000), (GPU, 1)]);
        assert!(req.fits_in(&avail));
        let rem = avail.checked_sub(&req).unwrap();
        assert_eq!(rem.get(CPU), 3000);
        assert_eq!(rem.get(GPU), 1);
        assert_eq!(rem.get(MEMORY), 8 << 30);
        let back = rem.plus(&req);
        assert_eq!(back, avail);
    }

    #[test]
    fn missing_resource_blocks_fit() {
        let avail = rv(&[(CPU, 4000)]);
        let req = rv(&[(CPU, 100), (GPU, 1)]);
        assert!(!req.fits_in(&avail));
        assert!(avail.checked_sub(&req).is_none());
    }

    #[test]
    fn zero_entries_are_pruned() {
        let mut r = rv(&[(CPU, 100)]);
        r.set(CPU, 0);
        assert!(r.is_empty());
        assert_eq!(r.get(CPU), 0);
    }

    #[test]
    fn mig_and_fpga_names() {
        assert_eq!(mig_resource(1, 5), "nvidia.com/mig-1g.5gb");
        assert_eq!(mig_resource(7, 40), "nvidia.com/mig-7g.40gb");
        assert_eq!(fpga_resource("U250"), "xilinx.com/fpga-u250");
    }

    #[test]
    fn dominant_share_takes_max() {
        let cap = rv(&[(CPU, 1000), (GPU, 4)]);
        let used = rv(&[(CPU, 100), (GPU, 3)]);
        assert!((used.dominant_share(&cap) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn scaled_multiplies() {
        let r = rv(&[(CPU, 500), (GPU, 1)]).scaled(3);
        assert_eq!(r.get(CPU), 1500);
        assert_eq!(r.get(GPU), 3);
    }

    #[test]
    #[should_panic]
    fn negative_quantity_rejected() {
        rv(&[(CPU, -1)]);
    }

    #[test]
    fn display_formats_units() {
        let r = rv(&[(CPU, 1500), (MEMORY, 2 << 30), (GPU, 1)]);
        let s = r.to_string();
        assert!(s.contains("cpu=1500m"));
        assert!(s.contains("2.0 GiB"));
        assert!(s.contains("nvidia.com/gpu=1"));
    }
}
