//! Cluster nodes: capacity, labels, taints, and the GPU devices they host.

use std::collections::BTreeMap;

use crate::cluster::resources::{ResourceVec, CPU, MEMORY, STORAGE};
use crate::gpu::GpuDevice;
use crate::util::codec::{CodecError, Dec, Enc, Reader};

/// Kubernetes-style taint effect (only NoSchedule is needed here).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Taint {
    pub key: String,
    pub value: String,
}

/// A (possibly virtual) cluster node.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub labels: BTreeMap<String, String>,
    pub taints: Vec<Taint>,
    /// Full capacity including extended resources from GPU devices.
    pub capacity: ResourceVec,
    /// Capacity minus system reservation; the scheduler's budget.
    pub allocatable: ResourceVec,
    pub gpus: Vec<GpuDevice>,
    /// Virtual nodes are backed by a remote provider (InterLink).
    pub virtual_node: bool,
    pub ready: bool,
}

impl Node {
    /// Build a physical node; extended resources derived from `gpus`.
    pub fn physical(
        name: impl Into<String>,
        cpu_cores: i64,
        mem_bytes: i64,
        disk_bytes: i64,
        gpus: Vec<GpuDevice>,
    ) -> Node {
        let name = name.into();
        let mut capacity = ResourceVec::new()
            .with(CPU, cpu_cores * 1000)
            .with(MEMORY, mem_bytes)
            .with(STORAGE, disk_bytes);
        for g in &gpus {
            capacity.add(&g.extended_resources());
        }
        // Reserve ~2 cores + 8 GiB for system daemons, like real kubelets do.
        let mut allocatable = capacity.clone();
        allocatable.set(CPU, (capacity.get(CPU) - 2000).max(0));
        allocatable.set(MEMORY, (capacity.get(MEMORY) - (8 << 30)).max(0));
        let mut labels = BTreeMap::new();
        labels.insert("kubernetes.io/hostname".into(), name.clone());
        if gpus.iter().any(|g| !g.model.is_fpga()) {
            labels.insert("nvidia.com/gpu.present".into(), "true".into());
        }
        Node {
            name,
            labels,
            taints: Vec::new(),
            capacity,
            allocatable,
            gpus,
            virtual_node: false,
            ready: true,
        }
    }

    /// Build a virtual (InterLink-backed) node with synthetic capacity.
    pub fn virtual_node(name: impl Into<String>, capacity: ResourceVec) -> Node {
        let name = name.into();
        let mut labels = BTreeMap::new();
        labels.insert("kubernetes.io/hostname".into(), name.clone());
        labels.insert("type".into(), "virtual-kubelet".into());
        Node {
            name,
            labels,
            // Real InterLink nodes carry a taint so only offload-tolerant
            // pods land there.
            taints: vec![Taint { key: "virtual-node.interlink/no-schedule".into(), value: "true".into() }],
            allocatable: capacity.clone(),
            capacity,
            gpus: Vec::new(),
            virtual_node: true,
            ready: true,
        }
    }

    /// Re-derive extended resources after a MIG repartition.
    pub fn refresh_extended_resources(&mut self) {
        // wipe existing extended entries, rebuild from devices
        let mut cap = ResourceVec::new()
            .with(CPU, self.capacity.get(CPU))
            .with(MEMORY, self.capacity.get(MEMORY))
            .with(STORAGE, self.capacity.get(STORAGE));
        for g in &self.gpus {
            cap.add(&g.extended_resources());
        }
        let mut alloc = cap.clone();
        alloc.set(CPU, self.allocatable.get(CPU));
        alloc.set(MEMORY, self.allocatable.get(MEMORY));
        self.capacity = cap;
        self.allocatable = alloc;
    }

    pub fn has_label(&self, k: &str, v: &str) -> bool {
        self.labels.get(k).map(|x| x == v).unwrap_or(false)
    }
}

// --------------------------------------------------------------- durability

impl Enc for Taint {
    fn enc(&self, b: &mut Vec<u8>) {
        self.key.enc(b);
        self.value.enc(b);
    }
}

impl Dec for Taint {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Taint { key: Dec::dec(r)?, value: Dec::dec(r)? })
    }
}

impl Enc for Node {
    fn enc(&self, b: &mut Vec<u8>) {
        self.name.enc(b);
        self.labels.enc(b);
        self.taints.enc(b);
        self.capacity.enc(b);
        self.allocatable.enc(b);
        self.gpus.enc(b);
        self.virtual_node.enc(b);
        self.ready.enc(b);
    }
}

impl Dec for Node {
    fn dec(r: &mut Reader<'_>) -> Result<Self, CodecError> {
        Ok(Node {
            name: Dec::dec(r)?,
            labels: Dec::dec(r)?,
            taints: Dec::dec(r)?,
            capacity: Dec::dec(r)?,
            allocatable: Dec::dec(r)?,
            gpus: Dec::dec(r)?,
            virtual_node: Dec::dec(r)?,
            ready: Dec::dec(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuModel, MigLayout};

    #[test]
    fn physical_node_aggregates_gpu_resources() {
        let gpus = vec![
            GpuDevice::whole("g0", GpuModel::TeslaT4),
            GpuDevice::whole("g1", GpuModel::TeslaT4),
        ];
        let n = Node::physical("s1", 64, 750 << 30, 12 << 40, gpus);
        assert_eq!(n.capacity.get("nvidia.com/gpu"), 2);
        assert_eq!(n.capacity.get(CPU), 64_000);
        assert_eq!(n.allocatable.get(CPU), 62_000);
        assert!(n.has_label("nvidia.com/gpu.present", "true"));
    }

    #[test]
    fn refresh_after_repartition_swaps_resources() {
        let mut n = Node::physical(
            "s2",
            128,
            1024 << 30,
            12 << 40,
            vec![GpuDevice::whole("g0", GpuModel::A100_40GB)],
        );
        assert_eq!(n.allocatable.get("nvidia.com/gpu"), 1);
        let layout = MigLayout::max_sharing(GpuModel::A100_40GB).unwrap();
        n.gpus[0].repartition(layout).unwrap();
        n.refresh_extended_resources();
        assert_eq!(n.allocatable.get("nvidia.com/gpu"), 0);
        assert_eq!(n.allocatable.get("nvidia.com/mig-1g.5gb"), 7);
    }

    #[test]
    fn virtual_node_is_tainted() {
        let n = Node::virtual_node("leonardo", ResourceVec::cpu_millis(1_000_000));
        assert!(n.virtual_node);
        assert_eq!(n.taints.len(), 1);
    }
}
