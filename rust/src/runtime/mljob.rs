//! ML job runners: the real payloads the platform executes for users.
//!
//! [`TrainRunner`] drives the AOT-compiled `train_step` artifact: it owns the
//! optimizer state (theta, m, v) as host vectors, feeds token batches from
//! the corpus, and records the loss curve. [`InferRunner`] serves
//! last-position logits. Python is never involved — the artifacts were
//! compiled once at build time.

use crate::runtime::manifest::{Manifest, ModelEntry};
use crate::runtime::pjrt::{as_f32_scalar, f32_scalar, f32_vec, i32_tensor, Engine};

/// Sequential-batch sampler over the tokenised corpus (deterministic).
pub struct CorpusSampler {
    corpus: Vec<i32>,
    cursor: usize,
    batch: usize,
    seq_plus_1: usize,
    vocab: i32,
}

impl CorpusSampler {
    pub fn new(corpus: Vec<i32>, batch: usize, seq: usize, vocab: usize) -> Self {
        CorpusSampler { corpus, cursor: 0, batch, seq_plus_1: seq + 1, vocab: vocab as i32 }
    }

    /// Next `[batch, seq+1]` token block (wrapping; clips to vocab).
    pub fn next_block(&mut self) -> Vec<i32> {
        let need = self.batch * self.seq_plus_1;
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            let t = self.corpus[self.cursor % self.corpus.len()].min(self.vocab - 1).max(0);
            out.push(t);
            self.cursor += 1;
        }
        out
    }
}

/// A training job bound to one model preset.
pub struct TrainRunner {
    pub preset: String,
    artifact_key: String,
    batch: usize,
    seq: usize,
    theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u32,
    sampler: CorpusSampler,
    pub losses: Vec<f32>,
    pub flops_per_step: f64,
}

impl TrainRunner {
    /// Prepare a runner: compiles the artifact (cache-hit after first use)
    /// and loads theta0 + corpus from the manifest blobs.
    pub fn new(
        engine: &mut Engine,
        manifest: &Manifest,
        preset: &str,
        pallas_variant: bool,
    ) -> anyhow::Result<TrainRunner> {
        let model: &ModelEntry = manifest
            .model(preset)
            .ok_or_else(|| anyhow::anyhow!("no model preset {preset}"))?;
        let art_name = if pallas_variant { "train_step_pallas" } else { "train_step" };
        let art = model
            .artifact(art_name)
            .ok_or_else(|| anyhow::anyhow!("preset {preset} lacks artifact {art_name}"))?;
        engine.load_artifact(art)?;
        let theta = manifest.load_theta0(preset)?;
        let n = theta.len();
        let corpus = manifest.load_corpus()?;
        Ok(TrainRunner {
            preset: preset.to_string(),
            artifact_key: Engine::artifact_key(art),
            batch: model.batch,
            seq: model.seq,
            theta,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            sampler: CorpusSampler::new(corpus, model.batch, model.seq, model.vocab),
            losses: Vec::new(),
            flops_per_step: model.flops_per_train_step,
        })
    }

    pub fn param_count(&self) -> usize {
        self.theta.len()
    }

    pub fn steps_done(&self) -> u32 {
        self.step
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, engine: &mut Engine) -> anyhow::Result<f32> {
        self.step += 1;
        let tokens = self.sampler.next_block();
        let tok_lit = i32_tensor(&tokens, &[self.batch as i64, (self.seq + 1) as i64])?;
        let args = [
            tok_lit,
            f32_scalar(self.step as f32),
            f32_vec(&self.theta),
            f32_vec(&self.m),
            f32_vec(&self.v),
        ];
        let out = engine.execute(&self.artifact_key, &args)?;
        anyhow::ensure!(out.len() == 4, "train_step must return 4 outputs, got {}", out.len());
        let loss = as_f32_scalar(&out[0])?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {}: {loss}", self.step);
        self.theta = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        self.m = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        self.v = out[3].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Run `n` steps; returns (first, last) loss.
    pub fn run(&mut self, engine: &mut Engine, n: u32) -> anyhow::Result<(f32, f32)> {
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..n {
            last = self.step(engine)?;
            first.get_or_insert(last);
        }
        Ok((first.unwrap_or(last), last))
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }
}

/// Inference runner over the `infer_step` artifact.
pub struct InferRunner {
    artifact_key: String,
    batch: usize,
    seq: usize,
    vocab: usize,
    theta: Vec<f32>,
}

impl InferRunner {
    pub fn new(
        engine: &mut Engine,
        manifest: &Manifest,
        preset: &str,
        theta: Vec<f32>,
    ) -> anyhow::Result<InferRunner> {
        let model = manifest
            .model(preset)
            .ok_or_else(|| anyhow::anyhow!("no model preset {preset}"))?;
        let art = model
            .artifact("infer_step")
            .ok_or_else(|| anyhow::anyhow!("no infer_step artifact"))?;
        engine.load_artifact(art)?;
        anyhow::ensure!(theta.len() == model.param_count, "theta size mismatch");
        Ok(InferRunner {
            artifact_key: Engine::artifact_key(art),
            batch: model.batch,
            seq: model.seq,
            vocab: model.vocab,
            theta,
        })
    }

    /// Last-position logits for a `[batch, seq]` token block.
    pub fn logits(&self, engine: &mut Engine, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch * self.seq, "token block size");
        let tok = i32_tensor(tokens, &[self.batch as i64, self.seq as i64])?;
        let out = engine.execute(&self.artifact_key, &[tok, f32_vec(&self.theta)])?;
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(logits.len() == self.batch * self.vocab, "logits size");
        Ok(logits)
    }

    /// Greedy next token for each row.
    pub fn greedy_next(&self, engine: &mut Engine, tokens: &[i32]) -> anyhow::Result<Vec<i32>> {
        let logits = self.logits(engine, tokens)?;
        Ok(logits
            .chunks(self.vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn training_reduces_loss_on_tiny() {
        let Some(m) = manifest() else { return };
        let mut eng = Engine::cpu().unwrap();
        let mut tr = TrainRunner::new(&mut eng, &m, "tiny", false).unwrap();
        let (first, last) = tr.run(&mut eng, 30).unwrap();
        // char-LM on the paper corpus: loss must fall decisively from ~ln(128)
        assert!(first > 4.0, "init loss ~ln(vocab): {first}");
        assert!(last < first - 0.5, "loss should fall: {first} -> {last}");
        assert_eq!(tr.losses.len(), 30);
        assert_eq!(tr.steps_done(), 30);
    }

    #[test]
    fn pallas_variant_matches_ref_first_step() {
        let Some(m) = manifest() else { return };
        if m.model("tiny").and_then(|e| e.artifact("train_step_pallas")).is_none() {
            eprintln!("skipping: pallas variant not exported");
            return;
        }
        let mut eng = Engine::cpu().unwrap();
        let mut a = TrainRunner::new(&mut eng, &m, "tiny", false).unwrap();
        let mut b = TrainRunner::new(&mut eng, &m, "tiny", true).unwrap();
        let la = a.step(&mut eng).unwrap();
        let lb = b.step(&mut eng).unwrap();
        assert!((la - lb).abs() < 1e-4, "ref {la} vs pallas {lb}");
    }

    #[test]
    fn infer_runner_produces_logits_and_tokens() {
        let Some(m) = manifest() else { return };
        let mut eng = Engine::cpu().unwrap();
        let entry = m.model("tiny").unwrap();
        let theta = m.load_theta0("tiny").unwrap();
        let inf = InferRunner::new(&mut eng, &m, "tiny", theta).unwrap();
        let tokens: Vec<i32> = (0..entry.batch * entry.seq).map(|i| (i % 60) as i32 + 32).collect();
        let logits = inf.logits(&mut eng, &tokens).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        let next = inf.greedy_next(&mut eng, &tokens).unwrap();
        assert_eq!(next.len(), entry.batch);
        assert!(next.iter().all(|&t| (t as usize) < entry.vocab));
    }

    #[test]
    fn corpus_sampler_wraps_and_clips() {
        let mut s = CorpusSampler::new(vec![1, 2, 300, 4, 5], 2, 2, 128);
        let b1 = s.next_block();
        assert_eq!(b1.len(), 6);
        assert!(b1.iter().all(|&t| t < 128));
        let b2 = s.next_block();
        assert_ne!(b1, b2); // cursor advanced
    }
}
