//! Runtime bridge (DESIGN.md S27/S28): PJRT artifact loading + execution,
//! ML job runners (training / inference over the AOT HLO), and the roofline
//! cost model used to price payloads in discrete-event mode.

pub mod costmodel;
pub mod manifest;
pub mod mljob;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use costmodel::CostModel;
pub use manifest::Manifest;
pub use mljob::{InferRunner, TrainRunner};
pub use pjrt::Engine;
