//! Roofline cost model: converts payload FLOPs into simulated durations per
//! accelerator, so discrete-event campaigns (E2–E4, E7) price ML jobs the
//! way the real platform's hardware would.
//!
//! Calibration: the `effective_fraction` defaults to 0.35 — typical measured
//! MFU/HFU for small-batch training on shared accelerators (far below peak,
//! consistent with the mixed interactive workloads the paper targets). MIG
//! slices scale by compute-slice fraction.

use crate::gpu::models::GpuModel;
use crate::sim::clock::Time;
use crate::sim::trace::GpuDemand;

/// Cost model parameters.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// fraction of peak tensor throughput actually achieved
    pub effective_fraction: f64,
    /// fixed per-job overhead (container + runtime init), seconds
    pub fixed_overhead: Time,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { effective_fraction: 0.35, fixed_overhead: 5.0 }
    }
}

impl CostModel {
    /// Seconds to run `flops` on `model`, scaled for a MIG slice fraction.
    pub fn duration(&self, flops: f64, model: GpuModel, demand: GpuDemand) -> Time {
        let peak = model.peak_tensor_tflops() * 1e12;
        let slice_frac = match demand {
            GpuDemand::None => 1.0, // CPU job: callers use cpu_duration
            GpuDemand::WholeGpu => 1.0,
            GpuDemand::MigSlice(c) => c as f64 / model.mig_compute_slices().max(1) as f64,
        };
        let rate = peak * self.effective_fraction * slice_frac;
        self.fixed_overhead + flops / rate.max(1.0)
    }

    /// CPU-only duration at a nominal per-core rate.
    pub fn cpu_duration(&self, flops: f64, cores: f64) -> Time {
        let rate = 25e9 * cores.max(0.25); // 25 GFLOPS/core effective
        self.fixed_overhead + flops / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_beats_t4() {
        let cm = CostModel::default();
        let f = 1e15;
        let a100 = cm.duration(f, GpuModel::A100_40GB, GpuDemand::WholeGpu);
        let t4 = cm.duration(f, GpuModel::TeslaT4, GpuDemand::WholeGpu);
        assert!(a100 < t4 / 3.0, "a100={a100} t4={t4}");
    }

    #[test]
    fn mig_slice_scales_linearly() {
        let cm = CostModel { fixed_overhead: 0.0, ..Default::default() };
        let f = 1e15;
        let whole = cm.duration(f, GpuModel::A100_40GB, GpuDemand::WholeGpu);
        let one_slice = cm.duration(f, GpuModel::A100_40GB, GpuDemand::MigSlice(1));
        assert!((one_slice / whole - 7.0).abs() < 1e-6);
    }

    #[test]
    fn overhead_dominates_tiny_jobs() {
        let cm = CostModel::default();
        let d = cm.duration(1.0, GpuModel::A100_40GB, GpuDemand::WholeGpu);
        assert!((d - cm.fixed_overhead).abs() < 1e-3);
    }

    #[test]
    fn cpu_duration_scales_with_cores() {
        let cm = CostModel { fixed_overhead: 0.0, ..Default::default() };
        assert!(cm.cpu_duration(1e12, 8.0) < cm.cpu_duration(1e12, 1.0) / 4.0);
    }
}
