//! Stub PJRT engine, compiled when the `pjrt` feature (and thus the `xla`
//! crate with its xla_extension C library) is absent.
//!
//! Mirrors the public surface of [`pjrt`](crate::runtime::pjrt) so the rest
//! of the crate — [`crate::runtime::mljob`], the CLI `train`/`validate`
//! subcommands, the e2e example — compiles unchanged. Every entry point
//! fails gracefully at runtime with a clear message instead of at link time,
//! which keeps the offline build green while real execution remains one
//! `--features pjrt` away.

use std::collections::HashSet;
use std::fmt;
use std::path::Path;

use crate::runtime::manifest::Artifact;

/// Compile/execute statistics (always zero in the stub).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
}

/// Error carried by stub literals and engine calls.
#[derive(Debug, Clone)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "built without the `pjrt` feature; rebuild with `--features pjrt`")
    }
}

impl std::error::Error for Unavailable {}

/// Placeholder for `xla::Literal`. Constructible (so the helper builders
/// keep their signatures) but never holds data.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}

/// The stub engine. `cpu()` refuses to construct it.
pub struct Engine {
    loaded: HashSet<String>,
    stats: EngineStats,
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Engine> {
        anyhow::bail!(
            "PJRT runtime unavailable: this binary was built without the `pjrt` \
             feature (the `xla` crate / xla_extension library is not linked). \
             Rebuild with `cargo build --features pjrt` to run real HLO artifacts."
        )
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn load(&mut self, _key: &str, _hlo_path: &Path) -> anyhow::Result<()> {
        anyhow::bail!("{Unavailable}")
    }

    /// Cache key for an artifact (same derivation as the real engine).
    pub fn artifact_key(art: &Artifact) -> String {
        art.file.display().to_string()
    }

    pub fn load_artifact(&mut self, art: &Artifact) -> anyhow::Result<()> {
        self.load(&Self::artifact_key(art), &art.file)
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.loaded.contains(key)
    }

    pub fn execute(&mut self, _key: &str, _args: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        anyhow::bail!("{Unavailable}")
    }
}

/// Build an f32 vector literal (stub).
pub fn f32_vec(_data: &[f32]) -> Literal {
    Literal
}

/// Build an i32 tensor literal (stub).
pub fn i32_tensor(_data: &[i32], _dims: &[i64]) -> anyhow::Result<Literal> {
    Ok(Literal)
}

/// Build an f32 scalar literal (stub).
pub fn f32_scalar(_v: f32) -> Literal {
    Literal
}

/// Extract an f32 scalar from a literal (stub: always errors).
pub fn as_f32_scalar(_l: &Literal) -> anyhow::Result<f32> {
    anyhow::bail!("{Unavailable}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_refuses_with_clear_message() {
        let err = Engine::cpu().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn literal_reads_error_gracefully() {
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(as_f32_scalar(&Literal).is_err());
        assert!(i32_tensor(&[1, 2], &[2]).is_ok());
    }
}
