//! Artifact manifest: the contract between `python/compile/aot.py` (build
//! time) and the Rust runtime (request time). Parsed with the in-house JSON
//! substrate — no serde, no Python at runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One tensor argument/output spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.str_field("name")?.to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as usize).collect())
                .unwrap_or_default(),
            dtype: j.str_field("dtype")?.to_string(),
        })
    }
}

/// One compiled artifact (an HLO text file + its signature).
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One exported model preset.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub preset: String,
    pub param_count: usize,
    pub flops_per_train_step: f64,
    pub seq: usize,
    pub batch: usize,
    pub vocab: usize,
    pub theta0: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl ModelEntry {
    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// gpu_burn payload entry.
#[derive(Debug, Clone)]
pub struct BurnEntry {
    pub name: String,
    pub file: PathBuf,
    pub n: usize,
    pub iters: usize,
    pub flops: f64,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
    pub burns: Vec<BurnEntry>,
    pub corpus: PathBuf,
    pub corpus_tokens: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading {}/manifest.json: {e} (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&raw).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        anyhow::ensure!(
            j.str_or("format", "") == "hlo-text-v1",
            "unsupported manifest format"
        );

        let mut models = Vec::new();
        if let Some(ms) = j.get("models").and_then(Json::as_obj) {
            for (preset, mj) in ms {
                let cfg = mj.get("config").ok_or_else(|| anyhow::anyhow!("model config"))?;
                let mut artifacts = Vec::new();
                if let Some(arts) = mj.get("artifacts").and_then(Json::as_obj) {
                    for (name, aj) in arts {
                        let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                            aj.get(key)
                                .and_then(Json::as_arr)
                                .map(|a| a.iter().map(TensorSpec::from_json).collect())
                                .unwrap_or_else(|| Ok(vec![]))
                        };
                        artifacts.push(Artifact {
                            name: name.clone(),
                            file: dir.join(aj.str_field("file")?),
                            args: parse_specs("args")?,
                            outputs: parse_specs("outputs")?,
                        });
                    }
                }
                models.push(ModelEntry {
                    preset: preset.clone(),
                    param_count: mj.i64_field("param_count")? as usize,
                    flops_per_train_step: mj.f64_or("flops_per_train_step", 0.0),
                    seq: cfg.i64_or("seq", 0) as usize,
                    batch: cfg.i64_or("batch", 0) as usize,
                    vocab: cfg.i64_or("vocab", 0) as usize,
                    theta0: dir.join(mj.str_or("theta0", "")),
                    artifacts,
                });
            }
        }

        let mut burns = Vec::new();
        if let Some(bs) = j.get("gpu_burn").and_then(Json::as_obj) {
            for (name, bj) in bs {
                burns.push(BurnEntry {
                    name: name.clone(),
                    file: dir.join(bj.str_field("file")?),
                    n: bj.i64_field("n")? as usize,
                    iters: bj.i64_field("iters")? as usize,
                    flops: bj.f64_or("flops", 0.0),
                });
            }
        }

        let corpus = j
            .get("corpus")
            .ok_or_else(|| anyhow::anyhow!("manifest missing corpus"))?;
        Ok(Manifest {
            corpus_tokens: corpus.i64_or("tokens", 0) as usize,
            corpus: dir.join(corpus.str_field("file")?),
            dir,
            models,
            burns,
        })
    }

    pub fn model(&self, preset: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.preset == preset)
    }

    /// Load the initial theta vector (little-endian f32).
    pub fn load_theta0(&self, preset: &str) -> anyhow::Result<Vec<f32>> {
        let m = self.model(preset).ok_or_else(|| anyhow::anyhow!("no preset {preset}"))?;
        let bytes = std::fs::read(&m.theta0)?;
        anyhow::ensure!(bytes.len() == m.param_count * 4, "theta0 size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load the tokenised corpus (little-endian i32).
    pub fn load_corpus(&self) -> anyhow::Result<Vec<i32>> {
        let bytes = std::fs::read(&self.corpus)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests run against the real artifacts dir when present (CI runs
    /// `make artifacts` first); otherwise they exercise the error paths.
    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.models.is_empty());
        let tiny = m.model("tiny").expect("tiny preset");
        assert!(tiny.param_count > 0);
        let ts = tiny.artifact("train_step").expect("train_step artifact");
        assert_eq!(ts.args.len(), 5);
        assert_eq!(ts.args[0].name, "tokens");
        assert_eq!(ts.outputs[0].name, "loss");
        assert!(ts.file.exists());
        // binary blobs load with the right sizes
        let theta = m.load_theta0("tiny").unwrap();
        assert_eq!(theta.len(), tiny.param_count);
        let corpus = m.load_corpus().unwrap();
        assert_eq!(corpus.len(), m.corpus_tokens);
    }

    #[test]
    fn missing_dir_gives_actionable_error() {
        let e = Manifest::load("/nonexistent/path").unwrap_err().to_string();
        assert!(e.contains("make artifacts"), "{e}");
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { name: "x".into(), shape: vec![4, 33], dtype: "int32".into() };
        assert_eq!(t.elements(), 132);
        let s = TensorSpec { name: "s".into(), shape: vec![], dtype: "float32".into() };
        assert_eq!(s.elements(), 1);
    }
}
