//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the coordinator's hot path.
//!
//! HLO *text* is the interchange format (see aot.py): jax ≥ 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Compilation results are cached per artifact.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::runtime::manifest::Artifact;

/// Compile/execute statistics (feeds §Perf).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
}

/// The engine. One PJRT CPU client + a compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: EngineStats,
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?,
            cache: HashMap::new(),
            stats: EngineStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Compile (or fetch from cache) the artifact's executable.
    pub fn load(&mut self, key: &str, hlo_path: &Path) -> anyhow::Result<()> {
        if self.cache.contains_key(key) {
            return Ok(());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", hlo_path.display()))?;
        self.stats.compiles += 1;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.cache.insert(key.to_string(), exe);
        Ok(())
    }

    /// Cache key for an artifact: the file path (unique per preset+variant;
    /// artifact *names* like "train_step" repeat across presets).
    pub fn artifact_key(art: &Artifact) -> String {
        art.file.display().to_string()
    }

    pub fn load_artifact(&mut self, art: &Artifact) -> anyhow::Result<()> {
        self.load(&Self::artifact_key(art), &art.file)
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.cache.contains_key(key)
    }

    /// Execute a cached executable. Inputs are borrowed literals; the
    /// (return_tuple=True) output is untupled into a Vec<Literal>.
    pub fn execute(&mut self, key: &str, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self
            .cache
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("artifact {key} not loaded"))?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {key}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result {key}: {e}"))?;
        self.stats.executions += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {key}: {e}"))
    }
}

/// Build an f32 vector literal.
pub fn f32_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Build an i32 tensor literal with the given dims.
pub fn i32_tensor(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e}"))
}

/// Build an f32 scalar literal.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 scalar from a literal.
pub fn as_f32_scalar(l: &xla::Literal) -> anyhow::Result<f32> {
    let v = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn burn_artifact_executes_and_is_cached() {
        let dir = artifacts_dir();
        let path = dir.join("gpu_burn_128x8.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut eng = Engine::cpu().unwrap();
        eng.load("burn", &path).unwrap();
        assert!(eng.is_loaded("burn"));
        let x: Vec<f32> = (0..128 * 128).map(|i| ((i % 17) as f32 - 8.0) * 0.01).collect();
        let lit = i32_dummy_f32(&x);
        let out = eng.execute("burn", &[lit]).unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y.len(), 128 * 128);
        assert!(y.iter().all(|v| v.is_finite()));
        // second load is a cache hit: compile count unchanged
        let c = eng.stats().compiles;
        eng.load("burn", &path).unwrap();
        assert_eq!(eng.stats().compiles, c);
        assert_eq!(eng.stats().executions, 1);
    }

    fn i32_dummy_f32(x: &[f32]) -> xla::Literal {
        xla::Literal::vec1(x).reshape(&[128, 128]).unwrap()
    }

    #[test]
    fn execute_unknown_key_errors() {
        let mut eng = Engine::cpu().unwrap();
        assert!(eng.execute("nope", &[]).is_err());
    }
}
