//! Open-loop synthetic inference traffic: deterministic, seeded request
//! arrival schedules for the serving subsystem.
//!
//! A [`TrafficEngine`] holds one [`TrafficPattern`] per `InferenceServer`:
//! a diurnal sinusoidal baseline (millions of users waking and sleeping)
//! plus a pre-sampled schedule of Poisson bursts (a conference demo, a
//! reprocessing campaign hammering a model). The platform facade drains
//! arrivals at every reconciliation tick — exactly like
//! [`ChaosEngine`](crate::sim::chaos::ChaosEngine) drains faults — so the
//! same seed and the same tick cadence yield the byte-identical arrival
//! sequence, which is what keeps golden-trace testing possible with
//! serving enabled.
//!
//! The generator is *open-loop*: arrivals never depend on what the serving
//! stack does with them. Overload shows up as queue growth and shed
//! requests downstream, not as back-pressure on the generator — the regime
//! SuperSONIC-style serving systems are sized against.

use std::collections::BTreeMap;

use crate::sim::clock::Time;
use crate::util::rng::Rng;

/// A transient surge of extra request rate on top of the baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct Burst {
    pub at: Time,
    pub duration: Time,
    /// Added requests/second while the burst is active.
    pub add_rps: f64,
}

/// One server's arrival-rate model.
#[derive(Debug, Clone)]
pub struct TrafficPattern {
    /// Target `InferenceServer` name.
    pub server: String,
    /// Mean baseline requests/second (diurnal midline).
    pub base_rps: f64,
    /// Fraction of the baseline swung by the diurnal cycle, in `[0, 1]`:
    /// rate peaks at `base*(1+a)` and troughs at `base*(1-a)`.
    pub diurnal_amplitude: f64,
    /// Seconds after midnight at which the diurnal peak lands.
    pub peak_at: Time,
    /// Active window `[start, stop)`; the rate is zero outside it (lets
    /// scenarios model a campaign ending, and scale-to-zero afterwards).
    pub active: (Time, Time),
    /// Pre-sampled burst schedule (sorted by `at` once generated).
    pub bursts: Vec<Burst>,
}

impl TrafficPattern {
    /// A flat always-on pattern with no diurnal swing and no bursts.
    pub fn flat(server: &str, rps: f64) -> Self {
        TrafficPattern {
            server: server.to_string(),
            base_rps: rps,
            diurnal_amplitude: 0.0,
            peak_at: 0.0,
            active: (0.0, f64::INFINITY),
            bursts: Vec::new(),
        }
    }

    /// Instantaneous arrival rate at `t` (requests/second).
    pub fn rate_at(&self, t: Time) -> f64 {
        if t < self.active.0 || t >= self.active.1 {
            return 0.0;
        }
        let day = std::f64::consts::TAU / 86_400.0;
        let diurnal = 1.0 + self.diurnal_amplitude * ((t - self.peak_at) * day).cos();
        let mut rate = self.base_rps * diurnal.max(0.0);
        for b in &self.bursts {
            if t >= b.at && t < b.at + b.duration {
                rate += b.add_rps;
            }
        }
        rate
    }
}

/// The arrival scheduler: per-server patterns drained window by window.
///
/// Arrival counts are Poisson draws against the rate integrated over the
/// drained window (midpoint rule), from one seeded RNG consumed in server
/// name order — deterministic for a fixed seed and tick cadence.
#[derive(Debug)]
pub struct TrafficEngine {
    seed: u64,
    rng: Rng,
    patterns: BTreeMap<String, TrafficPattern>,
    /// Cumulative arrivals per server.
    totals: BTreeMap<String, u64>,
    /// Sparse event log: pattern registrations and burst activations.
    log: Vec<(Time, String)>,
}

impl TrafficEngine {
    pub fn new(seed: u64) -> Self {
        TrafficEngine {
            seed,
            rng: Rng::new(seed),
            patterns: BTreeMap::new(),
            totals: BTreeMap::new(),
            log: Vec::new(),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Register (or replace) a server's pattern, effective `at`.
    pub fn add(&mut self, at: Time, mut pattern: TrafficPattern) {
        pattern.bursts.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        self.log.push((
            at,
            format!(
                "pattern {} base={:.1}rps amp={:.2} bursts={}",
                pattern.server,
                pattern.base_rps,
                pattern.diurnal_amplitude,
                pattern.bursts.len()
            ),
        ));
        self.patterns.insert(pattern.server.clone(), pattern);
    }

    /// Drop a server's pattern (its `InferenceServer` was deleted).
    pub fn remove(&mut self, at: Time, server: &str) {
        if self.patterns.remove(server).is_some() {
            self.log.push((at, format!("pattern-removed {server}")));
        }
    }

    pub fn pattern(&self, server: &str) -> Option<&TrafficPattern> {
        self.patterns.get(server)
    }

    /// Instantaneous rate for one server (0 if unregistered).
    pub fn rate_at(&self, server: &str, t: Time) -> f64 {
        self.patterns.get(server).map(|p| p.rate_at(t)).unwrap_or(0.0)
    }

    /// Drain the window `[from, to)`: one `(server, arrivals)` pair per
    /// registered pattern, in server name order. Burst activations crossing
    /// into the window land in the event log.
    pub fn drain(&mut self, from: Time, to: Time) -> Vec<(String, u64)> {
        let dt = (to - from).max(0.0);
        let mut out = Vec::with_capacity(self.patterns.len());
        for (name, p) in &self.patterns {
            for b in &p.bursts {
                if b.at >= from && b.at < to {
                    self.log.push((
                        b.at,
                        format!("burst {} +{:.1}rps for {:.0}s", name, b.add_rps, b.duration),
                    ));
                }
            }
            let lambda = p.rate_at(from + dt / 2.0) * dt;
            let n = self.rng.poisson(lambda);
            *self.totals.entry(name.clone()).or_insert(0) += n;
            out.push((name.clone(), n));
        }
        out
    }

    /// Cumulative arrivals generated for `server`.
    pub fn total_arrivals(&self, server: &str) -> u64 {
        self.totals.get(server).copied().unwrap_or(0)
    }

    /// The sparse event log rendered one line per event (golden traces).
    pub fn trace(&self) -> String {
        let mut s = String::new();
        for (at, line) in &self.log {
            s.push_str(&format!("{at:10.3} TRAFFIC {line}\n"));
        }
        s
    }
}

/// A randomized scenario family for burst schedules: expected bursts per
/// hour with uniform duration and amplitude ranges, sampled from one RNG
/// seeded by `seed` — same (plan, servers) pair, same schedule.
#[derive(Debug, Clone)]
pub struct TrafficPlan {
    pub seed: u64,
    /// Bursts are sampled in `[0, horizon)`.
    pub horizon: Time,
    pub bursts_per_hour: f64,
    pub burst_duration: (Time, Time),
    /// Added rate as a multiple of the pattern's baseline.
    pub burst_scale: (f64, f64),
}

impl Default for TrafficPlan {
    fn default() -> Self {
        TrafficPlan {
            seed: 42,
            horizon: 86_400.0,
            bursts_per_hour: 0.25,
            burst_duration: (120.0, 900.0),
            burst_scale: (1.0, 4.0),
        }
    }
}

impl TrafficPlan {
    /// Sample a burst schedule onto each baseline pattern and return the
    /// populated engine (registered at t=0).
    pub fn generate(&self, baselines: Vec<TrafficPattern>) -> TrafficEngine {
        let mut rng = Rng::new(self.seed);
        let mut eng = TrafficEngine::new(self.seed);
        let hours = self.horizon / 3600.0;
        for mut p in baselines {
            for _ in 0..rng.poisson(self.bursts_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                let duration = rng.range_f64(self.burst_duration.0, self.burst_duration.1);
                let scale = rng.range_f64(self.burst_scale.0, self.burst_scale.1);
                p.bursts.push(Burst { at, duration, add_rps: p.base_rps * scale });
            }
            eng.add(0.0, p);
        }
        eng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diurnal(server: &str) -> TrafficPattern {
        TrafficPattern {
            server: server.to_string(),
            base_rps: 100.0,
            diurnal_amplitude: 0.5,
            peak_at: 43_200.0,
            active: (0.0, f64::INFINITY),
            bursts: Vec::new(),
        }
    }

    #[test]
    fn same_seed_same_arrivals() {
        let plan = TrafficPlan { seed: 9, bursts_per_hour: 1.0, ..Default::default() };
        let mut a = plan.generate(vec![diurnal("cms-trk"), diurnal("atlas-ft")]);
        let mut b = plan.generate(vec![diurnal("cms-trk"), diurnal("atlas-ft")]);
        for w in 0..200 {
            let (f, t) = (w as f64 * 10.0, (w + 1) as f64 * 10.0);
            assert_eq!(a.drain(f, t), b.drain(f, t));
        }
        assert_eq!(a.trace(), b.trace());
        assert!(a.total_arrivals("cms-trk") > 0);
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a =
            TrafficPlan { seed: 1, ..Default::default() }.generate(vec![diurnal("m")]);
        let mut b =
            TrafficPlan { seed: 2, ..Default::default() }.generate(vec![diurnal("m")]);
        let draws_a: Vec<_> = (0..50).map(|w| a.drain(w as f64, w as f64 + 1.0)).collect();
        let draws_b: Vec<_> = (0..50).map(|w| b.drain(w as f64, w as f64 + 1.0)).collect();
        assert_ne!(draws_a, draws_b);
    }

    #[test]
    fn diurnal_peaks_and_troughs() {
        let p = diurnal("m");
        let peak = p.rate_at(43_200.0);
        let trough = p.rate_at(0.0);
        assert!((peak - 150.0).abs() < 1e-9, "peak={peak}");
        assert!((trough - 50.0).abs() < 1e-9, "trough={trough}");
    }

    #[test]
    fn bursts_add_and_expire() {
        let mut p = TrafficPattern::flat("m", 10.0);
        p.bursts.push(Burst { at: 100.0, duration: 50.0, add_rps: 90.0 });
        assert_eq!(p.rate_at(99.0), 10.0);
        assert_eq!(p.rate_at(100.0), 100.0);
        assert_eq!(p.rate_at(149.9), 100.0);
        assert_eq!(p.rate_at(150.0), 10.0);
    }

    #[test]
    fn inactive_window_is_silent() {
        let mut p = TrafficPattern::flat("m", 1000.0);
        p.active = (100.0, 200.0);
        let mut eng = TrafficEngine::new(7);
        eng.add(0.0, p);
        assert_eq!(eng.drain(0.0, 50.0), vec![("m".to_string(), 0)]);
        let (_, n) = eng.drain(120.0, 130.0)[0].clone();
        assert!(n > 0, "active window should produce arrivals");
        assert_eq!(eng.drain(250.0, 260.0), vec![("m".to_string(), 0)]);
    }

    #[test]
    fn removal_stops_arrivals_and_logs() {
        let mut eng = TrafficEngine::new(5);
        eng.add(0.0, TrafficPattern::flat("m", 50.0));
        eng.drain(0.0, 10.0);
        eng.remove(10.0, "m");
        assert!(eng.drain(10.0, 20.0).is_empty());
        assert!(eng.trace().contains("pattern-removed m"));
    }
}
