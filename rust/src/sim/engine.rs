//! Discrete-event simulation engine.
//!
//! A binary-heap future-event list with deterministic FIFO tie-breaking.
//! Components schedule closures at absolute times; [`Engine::run_until`]
//! pops events in order, advances the shared [`SimClock`], and dispatches.
//! All platform controllers (scheduler ticks, kubelet transitions, culler
//! sweeps, site heartbeats) run as events, so an entire multi-day cluster
//! campaign is a single-threaded, perfectly reproducible run.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use super::clock::{SimClock, Time};

/// Boxed event callback. Receives the engine so it can schedule follow-ups.
pub type EventFn = Box<dyn FnOnce(&mut Engine)>;

struct Scheduled {
    at: Time,
    seq: u64,
    f: EventFn,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time first; FIFO within equal times
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event loop.
pub struct Engine {
    clock: Arc<SimClock>,
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    dispatched: u64,
}

impl Engine {
    pub fn new(clock: Arc<SimClock>) -> Self {
        Engine { clock, heap: BinaryHeap::new(), seq: 0, dispatched: 0 }
    }

    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    pub fn now(&self) -> Time {
        use crate::sim::clock::Clock;
        self.clock.now()
    }

    /// Schedule `f` at absolute time `at` (clamped to now if in the past).
    pub fn at(&mut self, at: Time, f: impl FnOnce(&mut Engine) + 'static) {
        let at = at.max(self.now());
        self.seq += 1;
        self.heap.push(Scheduled { at, seq: self.seq, f: Box::new(f) });
    }

    /// Schedule `f` after a delay.
    pub fn after(&mut self, delay: Time, f: impl FnOnce(&mut Engine) + 'static) {
        let now = self.now();
        self.at(now + delay.max(0.0), f);
    }

    /// Schedule a periodic tick until `until`; `f` returns false to stop early.
    pub fn every(
        &mut self,
        period: Time,
        until: Time,
        mut f: impl FnMut(&mut Engine) -> bool + 'static,
    ) {
        fn tick(
            eng: &mut Engine,
            period: Time,
            until: Time,
            mut f: impl FnMut(&mut Engine) -> bool + 'static,
        ) {
            if !f(eng) {
                return;
            }
            let next = eng.now() + period;
            if next <= until {
                eng.at(next, move |e| tick(e, period, until, f));
            }
        }
        let start = self.now() + period;
        if start <= until {
            self.at(start, move |e| tick(e, period, until, f));
        }
    }

    /// Run events until the queue empties or the next event is after `t_end`.
    /// The clock finishes at exactly `t_end` (or the last event time).
    pub fn run_until(&mut self, t_end: Time) {
        while let Some(top) = self.heap.peek() {
            if top.at > t_end {
                break;
            }
            let ev = self.heap.pop().unwrap();
            self.clock.advance_to(ev.at);
            self.dispatched += 1;
            (ev.f)(self);
        }
        self.clock.advance_to(t_end);
    }

    /// Drain every event regardless of time (used by short unit tests).
    pub fn run_to_completion(&mut self) {
        while let Some(ev) = self.heap.pop() {
            self.clock.advance_to(ev.at);
            self.dispatched += 1;
            (ev.f)(self);
        }
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn engine() -> Engine {
        Engine::new(SimClock::new())
    }

    #[test]
    fn dispatches_in_time_order() {
        let mut e = engine();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let log = log.clone();
            e.at(t, move |_| log.borrow_mut().push(tag));
        }
        e.run_until(10.0);
        assert_eq!(*log.borrow(), vec!['a', 'b', 'c']);
        assert_eq!(e.now(), 10.0);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut e = engine();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in 0..5 {
            let log = log.clone();
            e.at(1.0, move |_| log.borrow_mut().push(tag));
        }
        e.run_until(2.0);
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn events_can_schedule_events() {
        let mut e = engine();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        e.at(1.0, move |eng| {
            *h.borrow_mut() += 1;
            let h2 = h.clone();
            eng.after(1.0, move |_| *h2.borrow_mut() += 1);
        });
        e.run_until(5.0);
        assert_eq!(*hits.borrow(), 2);
        assert!((e.now() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn run_until_leaves_future_events() {
        let mut e = engine();
        e.at(100.0, |_| panic!("must not run"));
        e.run_until(50.0);
        assert_eq!(e.pending(), 1);
        assert_eq!(e.now(), 50.0);
    }

    #[test]
    fn every_ticks_periodically_until_deadline() {
        let mut e = engine();
        let n = Rc::new(RefCell::new(0));
        let n2 = n.clone();
        e.every(1.0, 5.0, move |_| {
            *n2.borrow_mut() += 1;
            true
        });
        e.run_until(10.0);
        assert_eq!(*n.borrow(), 5);
    }

    #[test]
    fn every_stops_when_callback_returns_false() {
        let mut e = engine();
        let n = Rc::new(RefCell::new(0));
        let n2 = n.clone();
        e.every(1.0, 100.0, move |_| {
            *n2.borrow_mut() += 1;
            *n2.borrow() < 3
        });
        e.run_until(100.0);
        assert_eq!(*n.borrow(), 3);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut e = engine();
        e.at(5.0, |eng| {
            eng.at(1.0, |e2| assert!((e2.now() - 5.0).abs() < 1e-9));
        });
        e.run_until(10.0);
    }
}
