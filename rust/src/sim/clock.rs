//! Time source abstraction: one code path for simulation and real execution.
//!
//! Everything in the platform reads time through a [`Clock`]. In
//! discrete-event mode ([`SimClock`]) time advances only when the engine
//! dispatches the next event, letting the benchmarks sweep days of cluster
//! operation in milliseconds. In hardware-in-the-loop mode ([`WallClock`])
//! the same components run against the OS clock while job payloads execute
//! real HLO through PJRT.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Seconds since platform epoch (f64 — µs precision over simulated years).
pub type Time = f64;

pub trait Clock: Send + Sync {
    /// Current time, seconds since this clock's epoch.
    fn now(&self) -> Time;
}

/// Virtual clock advanced by the discrete-event engine.
#[derive(Debug, Default)]
pub struct SimClock {
    /// microseconds, atomically updated so readers never lock
    micros: AtomicU64,
}

impl SimClock {
    pub fn new() -> Arc<Self> {
        Arc::new(SimClock { micros: AtomicU64::new(0) })
    }

    pub fn advance_to(&self, t: Time) {
        let target = (t * 1e6) as u64;
        // monotonic: never step backwards even if events tie
        self.micros.fetch_max(target, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now(&self) -> Time {
        self.micros.load(Ordering::SeqCst) as f64 / 1e6
    }
}

/// Wall-clock time relative to construction.
#[derive(Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Arc<Self> {
        Arc::new(WallClock { start: Instant::now() })
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now(&self) -> Time {
        self.start.elapsed().as_secs_f64()
    }
}

/// Hours→seconds helper (configs speak hours for diurnal patterns).
pub const fn hours(h: f64) -> Time {
    h * 3600.0
}

pub const fn minutes(m: f64) -> Time {
    m * 60.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_monotonically() {
        let c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(10.5);
        assert!((c.now() - 10.5).abs() < 1e-6);
        c.advance_to(5.0); // must not go backwards
        assert!((c.now() - 10.5).abs() < 1e-6);
    }

    #[test]
    fn wall_clock_moves_forward() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now() > a);
    }

    #[test]
    fn unit_helpers() {
        assert_eq!(hours(2.0), 7200.0);
        assert_eq!(minutes(1.5), 90.0);
    }
}
