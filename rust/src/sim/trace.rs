//! Synthetic workload traces.
//!
//! The paper reports 78 registered users and 20 multi-user projects but no
//! public trace, so experiments E2/E3/E7 drive the platform with a synthetic
//! trace whose aggregate statistics follow the paper's narrative: interactive
//! JupyterLab sessions arrive with a diurnal (office-hours) intensity
//! profile; batch jobs are submitted around the clock with an evening bump;
//! session/job durations are log-normal; users are Zipf-popular (a few heavy
//! groups, a long tail), matching the "20 projects share 4 servers" setting.

use crate::sim::clock::{hours, Time};
use crate::util::rng::Rng;

/// What arrives.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalKind {
    /// Interactive JupyterLab session (spawn → work → idle-cull/stop).
    Interactive,
    /// Non-interactive batch job (Kueue workload).
    Batch,
}

/// One trace record.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at: Time,
    pub kind: ArrivalKind,
    pub user: String,
    pub project: String,
    /// Active work duration (seconds) the payload needs.
    pub duration: Time,
    /// GPU demand expressed as a MIG-profile-or-whole-GPU request.
    pub gpu: GpuDemand,
    pub cpu_millis: i64,
    pub mem_bytes: i64,
}

/// GPU request shapes seen on the platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuDemand {
    None,
    /// One MIG slice of the given compute-slice count (1,2,3,4,7).
    MigSlice(u8),
    /// One whole (non-MIG) GPU.
    WholeGpu,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub users: usize,
    pub projects: usize,
    /// Mean interactive sessions per hour at the office-hours peak.
    pub interactive_peak_per_hour: f64,
    /// Mean batch jobs per hour (flat component).
    pub batch_per_hour: f64,
    /// Session duration log-normal (mu, sigma) in log-seconds.
    pub session_mu_sigma: (f64, f64),
    /// Batch duration log-normal (mu, sigma) in log-seconds.
    pub batch_mu_sigma: (f64, f64),
    /// Fraction of interactive sessions requesting any GPU.
    pub interactive_gpu_frac: f64,
    /// Fraction of batch jobs requesting any GPU.
    pub batch_gpu_frac: f64,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            users: 78,    // paper §2: registered platform users
            projects: 20, // paper §2: allocated multi-user projects
            interactive_peak_per_hour: 6.0,
            batch_per_hour: 4.0,
            session_mu_sigma: ((2.0 * 3600.0f64).ln(), 0.8), // median ~2 h
            batch_mu_sigma: ((40.0 * 60.0f64).ln(), 1.0),    // median ~40 min
            interactive_gpu_frac: 0.7,
            batch_gpu_frac: 0.85,
            seed: 1,
        }
    }
}

/// Office-hours intensity multiplier in [0, 1]: low at night & weekends.
///
/// `t` is seconds from the campaign start, which is taken to be Monday 00:00.
pub fn diurnal_intensity(t: Time) -> f64 {
    let day = (t / hours(24.0)).floor() as i64;
    let hour_of_day = (t - day as f64 * hours(24.0)) / 3600.0;
    let weekend = day % 7 >= 5;
    let office = if (9.0..18.0).contains(&hour_of_day) {
        1.0
    } else if (7.0..9.0).contains(&hour_of_day) || (18.0..21.0).contains(&hour_of_day) {
        0.4
    } else {
        0.08
    };
    if weekend {
        office * 0.25
    } else {
        office
    }
}

/// Generate the full arrival list for `[0, horizon)` via thinning of a
/// non-homogeneous Poisson process.
pub fn generate(cfg: &TraceConfig, horizon: Time) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::new();

    // Interactive: thinned NHPP with diurnal intensity.
    let lambda_max = cfg.interactive_peak_per_hour / 3600.0;
    let mut t = 0.0;
    while t < horizon {
        t += rng.exp(lambda_max);
        if t >= horizon {
            break;
        }
        if rng.f64() <= diurnal_intensity(t) {
            out.push(make_arrival(cfg, &mut rng, t, ArrivalKind::Interactive));
        }
    }

    // Batch: flat Poisson with an evening bump (users queue work at day end,
    // the paper's "nights and weekends" opportunistic window).
    let lambda_batch = cfg.batch_per_hour / 3600.0;
    let mut t = 0.0;
    while t < horizon {
        t += rng.exp(lambda_batch * 1.5);
        if t >= horizon {
            break;
        }
        let day_frac = (t % hours(24.0)) / hours(24.0);
        let accept = if (0.66..0.95).contains(&day_frac) { 1.0 } else { 0.55 };
        if rng.f64() <= accept {
            out.push(make_arrival(cfg, &mut rng, t, ArrivalKind::Batch));
        }
    }

    out.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    out
}

fn make_arrival(cfg: &TraceConfig, rng: &mut Rng, at: Time, kind: ArrivalKind) -> Arrival {
    let user_idx = rng.zipf(cfg.users as u64, 1.1) as usize;
    let project_idx = user_idx % cfg.projects;
    let (mu, sigma) = match kind {
        ArrivalKind::Interactive => cfg.session_mu_sigma,
        ArrivalKind::Batch => cfg.batch_mu_sigma,
    };
    let duration = rng.lognormal(mu, sigma).clamp(60.0, hours(24.0));
    let gpu_frac = match kind {
        ArrivalKind::Interactive => cfg.interactive_gpu_frac,
        ArrivalKind::Batch => cfg.batch_gpu_frac,
    };
    let gpu = if rng.bool(gpu_frac) {
        match kind {
            // Interactive users mostly take small MIG slices; batch wants
            // bigger slices or whole GPUs.
            ArrivalKind::Interactive => match rng.weighted(&[0.55, 0.25, 0.12, 0.08]) {
                0 => GpuDemand::MigSlice(1),
                1 => GpuDemand::MigSlice(2),
                2 => GpuDemand::MigSlice(3),
                _ => GpuDemand::WholeGpu,
            },
            ArrivalKind::Batch => match rng.weighted(&[0.25, 0.3, 0.2, 0.25]) {
                0 => GpuDemand::MigSlice(2),
                1 => GpuDemand::MigSlice(3),
                2 => GpuDemand::MigSlice(7),
                _ => GpuDemand::WholeGpu,
            },
        }
    } else {
        GpuDemand::None
    };
    Arrival {
        at,
        kind,
        user: format!("user{user_idx:03}"),
        project: format!("project{project_idx:02}"),
        duration,
        gpu,
        cpu_millis: rng.range_i64(1, 8) * 1000,
        mem_bytes: rng.range_i64(2, 32) * (1 << 30),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg, hours(24.0));
        let b = generate(&cfg, hours(24.0));
        assert_eq!(a.len(), b.len());
        assert!(a.len() > 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.user, y.user);
        }
    }

    #[test]
    fn arrivals_sorted_and_in_horizon() {
        let tr = generate(&TraceConfig::default(), hours(48.0));
        for w in tr.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(tr.iter().all(|a| a.at < hours(48.0)));
    }

    #[test]
    fn interactive_concentrates_in_office_hours() {
        let cfg = TraceConfig { seed: 7, ..Default::default() };
        let tr = generate(&cfg, hours(5.0 * 24.0)); // Mon-Fri
        let (mut office, mut night) = (0, 0);
        for a in tr.iter().filter(|a| a.kind == ArrivalKind::Interactive) {
            let h = (a.at % hours(24.0)) / 3600.0;
            if (9.0..18.0).contains(&h) {
                office += 1;
            } else if !(7.0..21.0).contains(&h) {
                night += 1;
            }
        }
        assert!(office > 3 * night.max(1), "office={office} night={night}");
    }

    #[test]
    fn weekend_quieter_than_weekday() {
        let tr = generate(&TraceConfig { seed: 3, ..Default::default() }, hours(7.0 * 24.0));
        let weekday: usize = tr
            .iter()
            .filter(|a| a.kind == ArrivalKind::Interactive && (a.at / hours(24.0)) as i64 % 7 < 5)
            .count();
        let weekend: usize = tr
            .iter()
            .filter(|a| a.kind == ArrivalKind::Interactive && (a.at / hours(24.0)) as i64 % 7 >= 5)
            .count();
        // 5 weekdays vs 2 weekend days, weekend at 25% intensity
        assert!(weekday as f64 / 5.0 > 2.0 * (weekend as f64 / 2.0).max(0.5));
    }

    #[test]
    fn users_and_projects_within_bounds() {
        let cfg = TraceConfig::default();
        let tr = generate(&cfg, hours(72.0));
        for a in &tr {
            let u: usize = a.user[4..].parse().unwrap();
            let p: usize = a.project[7..].parse().unwrap();
            assert!(u < cfg.users);
            assert!(p < cfg.projects);
        }
    }

    #[test]
    fn durations_clamped() {
        let tr = generate(&TraceConfig::default(), hours(72.0));
        assert!(tr.iter().all(|a| (60.0..=hours(24.0)).contains(&a.duration)));
    }
}
