//! Fault injection: deterministic chaos schedules for resilience scenarios.
//!
//! A [`ChaosEngine`] holds a time-ordered schedule of [`Fault`]s — site
//! outages and recoveries, InterLink wire errors (timeouts, dropped
//! responses), remote job crashes (GPU ECC at the site), local node flaps
//! and GPU degradation. The platform facade drains due faults at every
//! reconciliation tick and applies them to the live subsystems, so faults
//! land at exactly the same virtual times run after run.
//!
//! Schedules come from two sources: tests inject specific faults by hand
//! ([`ChaosEngine::inject`]), and [`ChaosPlan::generate`] samples a whole
//! scenario from the seeded sim RNG — same seed, same targets ⇒ the
//! byte-identical schedule, which is what makes golden-trace testing
//! possible (run a scenario twice, diff the transition logs).

use crate::sim::clock::Time;
use crate::util::rng::Rng;

/// One injectable fault.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// The site's InterLink endpoint becomes unreachable (every wire call
    /// fails until recovery).
    SiteOutage { site: String },
    /// The endpoint answers again. The circuit breaker still gates
    /// placement until a half-open probe succeeds.
    SiteRecovery { site: String },
    /// The next `count` wire calls to the site time out before reaching it.
    WireTimeouts { site: String, count: u32 },
    /// The next `count` wire calls reach the site (side effects happen)
    /// but the response is lost on the way back.
    WireDrops { site: String, count: u32 },
    /// `count` remote jobs on the site crash (GPU ECC error, site-side
    /// node failure) and report `Failed` on the next status sync.
    RemoteJobFailures { site: String, count: u32 },
    /// A local node drops out of the cluster (kubelet stops heartbeating).
    NodeDown { node: String },
    /// The node heartbeats again and is schedulable.
    NodeUp { node: String },
    /// `count` units of an accelerator resource disappear from the node's
    /// allocatable (ECC page retirement, MIG slice loss).
    GpuDegrade { node: String, resource: String, count: i64 },
    /// The degraded accelerator units come back.
    GpuRecover { node: String, resource: String, count: i64 },
    /// The coordinator process dies and restarts: control-plane state is
    /// rebuilt from the last snapshot plus the WAL tail. A no-op (with a
    /// warning) unless durability is enabled. `shard` targets one
    /// coordinator shard of a federation (`None` = the sole/first
    /// coordinator — the pre-sharding semantics).
    CoordinatorCrash { shard: Option<usize> },
    /// The lease-holding leader dies and stays dead. With replication
    /// enabled the hot standby promotes once the lease expires; without
    /// it the fault degrades to [`Fault::CoordinatorCrash`] semantics.
    /// `shard` targets one coordinator shard (`None` as above).
    LeaderKill { shard: Option<usize> },
    /// The leader is partitioned from the standby: lease renewals and WAL
    /// shipping stop while the leader keeps (vainly) mutating state. At
    /// lease expiry the standby promotes and epoch fencing rejects the
    /// deposed leader's writes. A warned no-op without replication.
    LeaderIsolate,
}

impl Fault {
    /// Stable one-line rendering (golden traces diff these).
    pub fn describe(&self) -> String {
        match self {
            Fault::SiteOutage { site } => format!("site-outage {site}"),
            Fault::SiteRecovery { site } => format!("site-recovery {site}"),
            Fault::WireTimeouts { site, count } => format!("wire-timeouts {site} x{count}"),
            Fault::WireDrops { site, count } => format!("wire-drops {site} x{count}"),
            Fault::RemoteJobFailures { site, count } => {
                format!("remote-job-failures {site} x{count}")
            }
            Fault::NodeDown { node } => format!("node-down {node}"),
            Fault::NodeUp { node } => format!("node-up {node}"),
            Fault::GpuDegrade { node, resource, count } => {
                format!("gpu-degrade {node} -{count} {resource}")
            }
            Fault::GpuRecover { node, resource, count } => {
                format!("gpu-recover {node} +{count} {resource}")
            }
            // `None` keeps the exact pre-sharding strings: golden traces
            // recorded against the single-coordinator plane still match
            Fault::CoordinatorCrash { shard: None } => "coordinator-crash".to_string(),
            Fault::CoordinatorCrash { shard: Some(s) } => format!("coordinator-crash shard-{s}"),
            Fault::LeaderKill { shard: None } => "leader-kill".to_string(),
            Fault::LeaderKill { shard: Some(s) } => format!("leader-kill shard-{s}"),
            Fault::LeaderIsolate => "leader-isolate".to_string(),
        }
    }
}

/// A fault bound to an absolute injection time.
#[derive(Debug, Clone, PartialEq)]
pub struct Injection {
    pub at: Time,
    pub fault: Fault,
}

/// The fault scheduler: a sorted schedule plus the applied-fault log.
#[derive(Debug, Default)]
pub struct ChaosEngine {
    schedule: Vec<Injection>,
    cursor: usize,
    log: Vec<Injection>,
}

impl ChaosEngine {
    pub fn new() -> ChaosEngine {
        ChaosEngine::default()
    }

    /// Add a fault at an absolute time. The not-yet-applied tail of the
    /// schedule stays time-ordered; equal times keep insertion order.
    pub fn inject(&mut self, at: Time, fault: Fault) {
        self.schedule.push(Injection { at, fault });
        let cursor = self.cursor;
        self.schedule[cursor..].sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    }

    /// Drain every fault scheduled at or before `now`, in order. Applied
    /// faults move to the scenario log.
    pub fn due(&mut self, now: Time) -> Vec<Fault> {
        let mut out = Vec::new();
        while self.cursor < self.schedule.len() && self.schedule[self.cursor].at <= now {
            let inj = self.schedule[self.cursor].clone();
            self.cursor += 1;
            out.push(inj.fault.clone());
            self.log.push(inj);
        }
        out
    }

    /// Faults not yet applied.
    pub fn pending(&self) -> usize {
        self.schedule.len() - self.cursor
    }

    /// Applied faults, in application order.
    pub fn log(&self) -> &[Injection] {
        &self.log
    }

    /// The applied-fault log rendered one line per fault (golden traces).
    pub fn trace(&self) -> String {
        let mut s = String::new();
        for inj in &self.log {
            s.push_str(&format!("{:10.3} CHAOS {}\n", inj.at, inj.fault.describe()));
        }
        s
    }
}

/// A randomized scenario family: expected fault counts per *hour* per
/// target, with uniform duration ranges (seconds). Sampling draws from one
/// RNG seeded by `seed`, so a (plan, targets) pair always yields the same
/// schedule. Every outage/flap/degradation schedules its own recovery, so a
/// long-enough run always heals.
#[derive(Debug, Clone)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Faults are injected in `[0, horizon)`; recoveries may land later.
    pub horizon: Time,
    pub site_outages_per_hour: f64,
    pub outage_duration: (Time, Time),
    pub wire_faults_per_hour: f64,
    pub max_wire_burst: u32,
    pub remote_job_failures_per_hour: f64,
    pub node_flaps_per_hour: f64,
    pub node_down_duration: (Time, Time),
    pub gpu_degrades_per_hour: f64,
    pub gpu_degrade_duration: (Time, Time),
    /// Coordinator kill/restart events (needs `durability.enabled`).
    pub coordinator_crashes_per_hour: f64,
    /// Leader kills awaiting standby promotion (needs
    /// `replication.enabled`).
    pub leader_kills_per_hour: f64,
    /// Leader/standby network partitions (needs `replication.enabled`).
    pub leader_isolations_per_hour: f64,
    /// Coordinator shards in the targeted federation. At `<= 1` (the
    /// default) crash/kill faults carry `shard: None` and the plan is
    /// byte-identical to the pre-sharding generator; above 1 each
    /// crash/kill draws a shard target *after every other draw*, so the
    /// base schedule never reshuffles.
    pub shard_count: usize,
}

impl Default for ChaosPlan {
    fn default() -> Self {
        ChaosPlan {
            seed: 42,
            horizon: 3600.0,
            site_outages_per_hour: 0.5,
            outage_duration: (180.0, 900.0),
            wire_faults_per_hour: 2.0,
            max_wire_burst: 3,
            remote_job_failures_per_hour: 1.0,
            node_flaps_per_hour: 0.25,
            node_down_duration: (120.0, 600.0),
            gpu_degrades_per_hour: 0.25,
            gpu_degrade_duration: (300.0, 1200.0),
            coordinator_crashes_per_hour: 0.0,
            leader_kills_per_hour: 0.0,
            leader_isolations_per_hour: 0.0,
            shard_count: 0,
        }
    }
}

impl ChaosPlan {
    /// Generate a deterministic schedule against the given targets:
    /// federation `sites`, physical `nodes`, and `(node, resource)` pairs
    /// eligible for GPU degradation.
    pub fn generate(
        &self,
        sites: &[String],
        nodes: &[String],
        gpu_resources: &[(String, String)],
    ) -> ChaosEngine {
        let mut rng = Rng::new(self.seed);
        let mut eng = ChaosEngine::new();
        let hours = self.horizon / 3600.0;
        for site in sites {
            for _ in 0..rng.poisson(self.site_outages_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                let dur = rng.range_f64(self.outage_duration.0, self.outage_duration.1);
                eng.inject(at, Fault::SiteOutage { site: site.clone() });
                eng.inject(at + dur, Fault::SiteRecovery { site: site.clone() });
            }
            for _ in 0..rng.poisson(self.wire_faults_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                let count = 1 + rng.below(self.max_wire_burst.max(1) as u64) as u32;
                let fault = if rng.bool(0.5) {
                    Fault::WireTimeouts { site: site.clone(), count }
                } else {
                    Fault::WireDrops { site: site.clone(), count }
                };
                eng.inject(at, fault);
            }
            for _ in 0..rng.poisson(self.remote_job_failures_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                eng.inject(at, Fault::RemoteJobFailures { site: site.clone(), count: 1 });
            }
        }
        for node in nodes {
            for _ in 0..rng.poisson(self.node_flaps_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                let dur = rng.range_f64(self.node_down_duration.0, self.node_down_duration.1);
                eng.inject(at, Fault::NodeDown { node: node.clone() });
                eng.inject(at + dur, Fault::NodeUp { node: node.clone() });
            }
        }
        for (node, resource) in gpu_resources {
            for _ in 0..rng.poisson(self.gpu_degrades_per_hour * hours) {
                let at = rng.range_f64(0.0, self.horizon);
                let dur =
                    rng.range_f64(self.gpu_degrade_duration.0, self.gpu_degrade_duration.1);
                let count = 1 + rng.below(2) as i64;
                eng.inject(
                    at,
                    Fault::GpuDegrade {
                        node: node.clone(),
                        resource: resource.clone(),
                        count,
                    },
                );
                eng.inject(
                    at + dur,
                    Fault::GpuRecover {
                        node: node.clone(),
                        resource: resource.clone(),
                        count,
                    },
                );
            }
        }
        // drawn last so enabling crashes leaves every seeded schedule above
        // byte-identical to the crash-free plan with the same seed
        for _ in 0..rng.poisson(self.coordinator_crashes_per_hour * hours) {
            let at = rng.range_f64(0.0, self.horizon);
            eng.inject(at, Fault::CoordinatorCrash { shard: None });
        }
        // and leader faults after crashes, for the same reason: turning a
        // crash campaign into a failover campaign must not reshuffle it
        for _ in 0..rng.poisson(self.leader_kills_per_hour * hours) {
            let at = rng.range_f64(0.0, self.horizon);
            eng.inject(at, Fault::LeaderKill { shard: None });
        }
        for _ in 0..rng.poisson(self.leader_isolations_per_hour * hours) {
            let at = rng.range_f64(0.0, self.horizon);
            eng.inject(at, Fault::LeaderIsolate);
        }
        // shard targets are drawn after *everything* else, walking the
        // already-sorted schedule: plans with shard_count <= 1 draw
        // nothing here, so every pre-sharding seeded schedule above stays
        // byte-identical
        if self.shard_count > 1 {
            for inj in &mut eng.schedule {
                if let Fault::CoordinatorCrash { shard } | Fault::LeaderKill { shard } =
                    &mut inj.fault
                {
                    *shard = Some(rng.below(self.shard_count as u64) as usize);
                }
            }
        }
        eng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn targets() -> (Vec<String>, Vec<String>, Vec<(String, String)>) {
        (
            vec!["INFN-T1".to_string(), "CINECA-Leonardo".to_string()],
            vec!["cnaf-ai01".to_string(), "cnaf-ai02".to_string()],
            vec![("cnaf-ai01".to_string(), "nvidia.com/gpu".to_string())],
        )
    }

    #[test]
    fn same_seed_same_schedule() {
        let (sites, nodes, gpus) = targets();
        let plan = ChaosPlan { seed: 99, ..Default::default() };
        let mut a = plan.generate(&sites, &nodes, &gpus);
        let mut b = plan.generate(&sites, &nodes, &gpus);
        assert_eq!(a.due(f64::INFINITY), b.due(f64::INFINITY));
        assert_eq!(a.trace(), b.trace());
    }

    #[test]
    fn different_seeds_differ() {
        let (sites, nodes, gpus) = targets();
        let mut a = ChaosPlan { seed: 1, ..Default::default() }.generate(&sites, &nodes, &gpus);
        let mut b = ChaosPlan { seed: 2, ..Default::default() }.generate(&sites, &nodes, &gpus);
        assert_ne!(a.due(f64::INFINITY), b.due(f64::INFINITY));
        let _ = b.trace();
    }

    #[test]
    fn due_drains_in_time_order() {
        let mut eng = ChaosEngine::new();
        eng.inject(30.0, Fault::SiteOutage { site: "b".into() });
        eng.inject(10.0, Fault::SiteOutage { site: "a".into() });
        eng.inject(10.0, Fault::SiteRecovery { site: "a".into() });
        assert_eq!(eng.pending(), 3);
        let first = eng.due(10.0);
        assert_eq!(
            first,
            vec![
                Fault::SiteOutage { site: "a".into() },
                Fault::SiteRecovery { site: "a".into() }
            ]
        );
        assert_eq!(eng.pending(), 1);
        assert!(eng.due(20.0).is_empty());
        assert_eq!(eng.due(30.0), vec![Fault::SiteOutage { site: "b".into() }]);
        assert_eq!(eng.log().len(), 3);
    }

    #[test]
    fn outages_always_pair_with_recoveries() {
        let (sites, nodes, gpus) = targets();
        let plan = ChaosPlan {
            seed: 7,
            site_outages_per_hour: 6.0,
            node_flaps_per_hour: 6.0,
            ..Default::default()
        };
        let mut eng = plan.generate(&sites, &nodes, &gpus);
        let faults = eng.due(f64::INFINITY);
        let outages = faults.iter().filter(|f| matches!(f, Fault::SiteOutage { .. })).count();
        let recoveries =
            faults.iter().filter(|f| matches!(f, Fault::SiteRecovery { .. })).count();
        assert_eq!(outages, recoveries);
        let downs = faults.iter().filter(|f| matches!(f, Fault::NodeDown { .. })).count();
        let ups = faults.iter().filter(|f| matches!(f, Fault::NodeUp { .. })).count();
        assert_eq!(downs, ups);
        assert!(outages + downs > 0, "rates high enough to sample something");
    }

    #[test]
    fn leader_faults_never_reshuffle_the_base_schedule() {
        let (sites, nodes, gpus) = targets();
        let base = ChaosPlan {
            seed: 5,
            coordinator_crashes_per_hour: 1.0,
            ..Default::default()
        };
        let extended = ChaosPlan {
            leader_kills_per_hour: 2.0,
            leader_isolations_per_hour: 1.0,
            ..base.clone()
        };
        let a = base.generate(&sites, &nodes, &gpus).due(f64::INFINITY);
        let b = extended.generate(&sites, &nodes, &gpus).due(f64::INFINITY);
        let killed = b
            .iter()
            .filter(|f| matches!(f, Fault::LeaderKill { .. } | Fault::LeaderIsolate))
            .count();
        assert!(killed > 0, "rates high enough to sample leader faults");
        let b_base: Vec<Fault> = b
            .into_iter()
            .filter(|f| !matches!(f, Fault::LeaderKill { .. } | Fault::LeaderIsolate))
            .collect();
        assert_eq!(a, b_base, "existing draws must be byte-identical");
    }

    #[test]
    fn shard_targeting_never_reshuffles_the_base_schedule() {
        let (sites, nodes, gpus) = targets();
        let base = ChaosPlan {
            seed: 5,
            coordinator_crashes_per_hour: 1.0,
            leader_kills_per_hour: 1.0,
            ..Default::default()
        };
        let sharded = ChaosPlan { shard_count: 4, ..base.clone() };
        let a = base.generate(&sites, &nodes, &gpus).due(f64::INFINITY);
        let b = sharded.generate(&sites, &nodes, &gpus).due(f64::INFINITY);
        assert_eq!(a.len(), b.len(), "targeting adds no injections");
        let mut targeted = 0;
        for (fa, fb) in a.iter().zip(&b) {
            match (fa, fb) {
                (Fault::CoordinatorCrash { shard: None }, Fault::CoordinatorCrash { shard })
                | (Fault::LeaderKill { shard: None }, Fault::LeaderKill { shard }) => {
                    let s = shard.expect("sharded plan targets every crash/kill");
                    assert!(s < 4);
                    targeted += 1;
                }
                _ => assert_eq!(fa, fb, "non-coordinator faults must be untouched"),
            }
        }
        assert!(targeted > 0, "rates high enough to sample coordinator faults");
        // shard_count == 1 is the pre-sharding plan, byte-for-byte
        let c = ChaosPlan { shard_count: 1, ..base.clone() }
            .generate(&sites, &nodes, &gpus)
            .due(f64::INFINITY);
        assert_eq!(a, c);
    }

    #[test]
    fn shard_targeted_faults_render_their_target() {
        assert_eq!(Fault::CoordinatorCrash { shard: None }.describe(), "coordinator-crash");
        assert_eq!(
            Fault::CoordinatorCrash { shard: Some(2) }.describe(),
            "coordinator-crash shard-2"
        );
        assert_eq!(Fault::LeaderKill { shard: None }.describe(), "leader-kill");
        assert_eq!(Fault::LeaderKill { shard: Some(0) }.describe(), "leader-kill shard-0");
    }

    #[test]
    fn trace_is_stable_text() {
        let mut eng = ChaosEngine::new();
        eng.inject(1.5, Fault::WireTimeouts { site: "s".into(), count: 2 });
        eng.due(2.0);
        assert_eq!(eng.trace(), "     1.500 CHAOS wire-timeouts s x2\n");
    }
}
