//! Discrete-event simulation substrate (DESIGN.md S7): virtual clock, event
//! engine, and the synthetic workload trace generator that stands in for the
//! platform's production user trace.

pub mod clock;
pub mod engine;
pub mod trace;

pub use clock::{Clock, SimClock, Time, WallClock};
pub use engine::Engine;
