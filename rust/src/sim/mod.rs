//! Discrete-event simulation substrate (DESIGN.md S7): virtual clock, event
//! engine, the synthetic workload trace generator that stands in for the
//! platform's production user trace, and the chaos fault-injection engine
//! that schedules deterministic failure scenarios against it.

pub mod chaos;
pub mod clock;
pub mod engine;
pub mod trace;
pub mod traffic;

pub use chaos::{ChaosEngine, ChaosPlan, Fault};
pub use clock::{Clock, SimClock, Time, WallClock};
pub use engine::Engine;
pub use traffic::{Burst, TrafficEngine, TrafficPattern, TrafficPlan};
