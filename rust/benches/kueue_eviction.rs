//! E3 — Kueue opportunistic batch + interactive-first eviction (§3):
//! "designed to opportunistically run non-interactive workloads ... during
//! off-peak hours" / "running batch jobs are automatically evicted".
//!
//! Runs a 48 h diurnal campaign twice: with and without opportunistic
//! batch, and reports the series the paper's claim implies: interactive
//! spawn latency percentiles (must not degrade) and accelerator-utilization
//! day/night profile (must rise at night with batch on).

use aiinfn::hub::profiles::default_catalogue;
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::sim::clock::hours;
use aiinfn::sim::trace::{generate, ArrivalKind, GpuDemand, TraceConfig};
use aiinfn::util::bench::BenchGroup;
use aiinfn::util::stats::exact_percentile;

struct Outcome {
    spawn_p50: f64,
    spawn_p95: f64,
    evictions: u64,
    util_office: f64,
    util_night: f64,
    batch_done: u64,
}

fn campaign(with_batch: bool) -> Outcome {
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    let horizon = hours(48.0);
    // Heavier batch pressure than the default interactive-centric trace:
    // the experiment measures what happens when users *do* queue plenty of
    // off-peak work (the scenario §3 describes).
    let trace = generate(
        &TraceConfig { seed: 11, batch_per_hour: 40.0, ..Default::default() },
        horizon,
    );
    let catalogue = default_catalogue();
    let mut ti = 0;
    let mut office_samples = Vec::new();
    let mut night_samples = Vec::new();
    while p.now() < horizon {
        let until = (p.now() + 300.0).min(horizon);
        while ti < trace.len() && trace[ti].at <= until {
            let a = &trace[ti];
            ti += 1;
            match a.kind {
                ArrivalKind::Interactive => {
                    let prof = match a.gpu {
                        GpuDemand::None => &catalogue[0],
                        GpuDemand::MigSlice(1) => &catalogue[1],
                        GpuDemand::MigSlice(_) => &catalogue[2],
                        GpuDemand::WholeGpu => &catalogue[4],
                    };
                    let _ = p.spawn_session(&a.user, prof);
                }
                ArrivalKind::Batch if with_batch => {
                    let _ = p.submit_ml_training(&a.user, &a.project, a.duration * 3e13, a.gpu, false);
                }
                _ => {}
            }
        }
        p.run_for(until - p.now(), 60.0);
        let h = (p.now() / 3600.0) % 24.0;
        let u = p.accelerator_utilization();
        if (9.0..18.0).contains(&h) {
            office_samples.push(u);
        } else if !(7.0..21.0).contains(&h) {
            night_samples.push(u);
        }
    }
    let mut lat = p.metrics().interactive_spawn_latencies.clone();
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    Outcome {
        spawn_p50: exact_percentile(&mut lat.clone(), 50.0),
        spawn_p95: exact_percentile(&mut lat, 95.0),
        evictions: p.metrics().evictions,
        util_office: avg(&office_samples),
        util_night: avg(&night_samples),
        batch_done: p.metrics().local_completions + p.metrics().remote_completions,
    }
}

fn main() {
    let mut g = BenchGroup::new("E3-kueue-eviction");

    let base = campaign(false);
    let opp = campaign(true);

    println!("\n| metric | interactive-only | + opportunistic batch |");
    println!("|---|---|---|");
    println!("| spawn latency p50 (s) | {:.1} | {:.1} |", base.spawn_p50, opp.spawn_p50);
    println!("| spawn latency p95 (s) | {:.1} | {:.1} |", base.spawn_p95, opp.spawn_p95);
    println!("| office-hours util | {:.1}% | {:.1}% |", base.util_office * 100.0, opp.util_office * 100.0);
    println!("| night util | {:.1}% | {:.1}% |", base.util_night * 100.0, opp.util_night * 100.0);
    println!("| batch completions | 0 | {} |", opp.batch_done);
    println!("| batch evictions | 0 | {} |", opp.evictions);

    g.record_value("spawn-p95-base", base.spawn_p95, "s");
    g.record_value("spawn-p95-opportunistic", opp.spawn_p95, "s");
    g.record_value("night-util-base", base.util_night * 100.0, "%");
    g.record_value("night-util-opportunistic", opp.util_night * 100.0, "%");
    g.record_value("evictions", opp.evictions as f64, "evictions");
    g.record_value("batch-completions", opp.batch_done as f64, "jobs");

    // the paper's qualitative claims, asserted:
    assert!(
        opp.util_night > 2.0 * base.util_night && opp.util_night > base.util_night + 0.05,
        "opportunistic batch must lift night utilization: {:.3} vs {:.3}",
        opp.util_night,
        base.util_night
    );
    assert!(opp.batch_done > 0, "batch must complete");
    // interactive experience must not collapse (within container cold-start
    // noise + one eviction latency)
    assert!(
        opp.spawn_p95 <= base.spawn_p95 + 120.0,
        "interactive latency degraded: {} → {}",
        base.spawn_p95,
        opp.spawn_p95
    );
    println!("\nE3 Kueue-eviction checks PASSED");
}
