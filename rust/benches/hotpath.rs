//! §Perf — L3 hot-path microbenchmarks against the DESIGN.md targets:
//!   scheduler ≥ 50k placements/s, InterLink round-trip < 50 µs in-proc,
//!   TSDB ingest ≥ 1M samples/s, JSON wire codec, Kueue admission.
//! Plus the PJRT execute path (train-step latency) when artifacts exist.

use aiinfn::cluster::node::Node;
use aiinfn::cluster::pod::{Payload, PodSpec};
use aiinfn::cluster::resources::ResourceVec;
use aiinfn::cluster::scheduler::Scheduler;
use aiinfn::cluster::store::ClusterStore;
use aiinfn::monitoring::tsdb::{SeriesKey, Tsdb};
use aiinfn::queue::kueue::{ClusterQueue, Kueue, LocalQueue, PriorityClass};
use aiinfn::util::bench::{black_box, BenchGroup};
use aiinfn::util::json::Json;

fn sched_bench(g: &mut BenchGroup) {
    // 16-node cluster, schedule 1000 CPU pods per iteration
    let nodes: Vec<Node> = (0..16)
        .map(|i| Node::physical(format!("n{i:02}"), 128, 1024 << 30, 10 << 40, vec![]))
        .collect();
    let n_pods = 1000u64;
    g.bench_elements("scheduler-place-1k-pods-16-nodes", n_pods, || {
        let mut store = ClusterStore::new();
        for n in &nodes {
            store.add_node(n.clone(), 0.0);
        }
        for i in 0..n_pods {
            store.create_pod(
                PodSpec::new(
                    format!("p{i}"),
                    ResourceVec::cpu_millis(1000),
                    Payload::Sleep { duration: 1.0 },
                ),
                0.0,
            );
        }
        let sched = Scheduler::default();
        let (placed, _) = sched.schedule_pending(&mut store, 0.0);
        assert_eq!(placed.len(), n_pods as usize);
        black_box(placed.len());
    });

    // single-decision latency on a busy cluster
    let mut store = ClusterStore::new();
    for n in &nodes {
        store.add_node(n.clone(), 0.0);
    }
    for i in 0..500 {
        store.create_pod(
            PodSpec::new(format!("busy{i}"), ResourceVec::cpu_millis(2000), Payload::Sleep { duration: 1.0 }),
            0.0,
        );
    }
    let sched = Scheduler::default();
    sched.schedule_pending(&mut store, 0.0);
    let probe = PodSpec::new("probe", ResourceVec::cpu_millis(1500), Payload::Sleep { duration: 1.0 });
    g.bench("scheduler-single-decision", || {
        black_box(sched.select_node(&store, &probe).ok());
    });
}

fn kueue_bench(g: &mut BenchGroup) {
    g.bench_elements("kueue-submit-admit-200", 200, || {
        let mut k = Kueue::new();
        k.add_cluster_queue(ClusterQueue {
            name: "cq".into(),
            cohort: None,
            nominal: ResourceVec::cpu_millis(1_000_000),
            used: ResourceVec::new(),
            can_borrow: false,
            can_lend: false,
        });
        k.add_local_queue(LocalQueue { name: "lq".into(), cluster_queue: "cq".into() });
        for i in 0..200 {
            k.submit(format!("w{i}"), "lq", PriorityClass::Batch, ResourceVec::cpu_millis(4000), 0.0)
                .unwrap();
        }
        black_box(k.admit_pass(0.0).admitted.len());
    });
}

fn tsdb_bench(g: &mut BenchGroup) {
    let mut db = Tsdb::new(3600.0);
    let key = SeriesKey::new("m", &[("node", "n1")]);
    let mut t = 0.0;
    g.bench_elements("tsdb-ingest-single-series-1k", 1000, || {
        for _ in 0..1000 {
            t += 1.0;
            db.ingest(key.clone(), t, t);
        }
    });
}

fn wire_bench(g: &mut BenchGroup) {
    use aiinfn::offload::interlink::{Request, WirePod};
    let spec = PodSpec::new(
        "train-01",
        ResourceVec::cpu_millis(4000).with("nvidia.com/mig-1g.5gb", 2),
        Payload::MlJob { artifact: "train_step_small".into(), steps: 100 },
    );
    let pod = WirePod::from_spec(&spec, 600.0);
    let req = Request::Create { pod, token: "tok".into() };
    let encoded = req.encode();
    g.bench("interlink-encode", || {
        black_box(req.encode());
    });
    g.bench("interlink-decode", || {
        black_box(Request::decode(&encoded).unwrap());
    });
    let doc = std::fs::read_to_string(aiinfn::platform::default_config_path()).unwrap();
    g.bench_elements("json-parse-platform-config", doc.len() as u64, || {
        black_box(Json::parse(&doc).unwrap());
    });
}

fn pjrt_bench(g: &mut BenchGroup) {
    use aiinfn::runtime::{Engine, Manifest, TrainRunner};
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
        return;
    };
    let mut eng = Engine::cpu().unwrap();
    let mut tr = TrainRunner::new(&mut eng, &manifest, "tiny", false).unwrap();
    g.bench("pjrt-train-step-tiny", || {
        black_box(tr.step(&mut eng).unwrap());
    });
    if manifest.model("small").is_some() {
        let mut trs = TrainRunner::new(&mut eng, &manifest, "small", false).unwrap();
        g.bench("pjrt-train-step-small", || {
            black_box(trs.step(&mut eng).unwrap());
        });
    }
}

fn main() {
    let mut g = BenchGroup::new("Perf-hotpath");
    sched_bench(&mut g);
    kueue_bench(&mut g);
    tsdb_bench(&mut g);
    wire_bench(&mut g);
    pjrt_bench(&mut g);

    // DESIGN.md §Perf gate summary
    println!("\n== §Perf targets ==");
    for r in g.results() {
        let per_sec = r.per_sec();
        match r.name.as_str() {
            "scheduler-place-1k-pods-16-nodes" => {
                println!("scheduler: {:.0} placements/s (target ≥ 50k)", per_sec);
            }
            "tsdb-ingest-single-series-1k" => {
                println!("tsdb ingest: {:.2}M samples/s (target ≥ 1M)", per_sec / 1e6);
            }
            "interlink-decode" => {
                println!("interlink decode: {:?} (round-trip target < 50µs)", r.median);
            }
            _ => {}
        }
    }
}
