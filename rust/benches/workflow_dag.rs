//! E5 — Snakemake workflows (§3): "explicit handling of job dependencies
//! and reproducible workflows ... job dependencies are managed by a
//! dedicated controller."
//!
//! Builds a fan-out pipeline (preprocess → train×N → evaluate → summary),
//! runs it through the platform controller, and compares makespan against
//! the sequential baseline and the critical-path bound. Also measures DAG
//! resolution throughput.

use std::collections::{HashMap, HashSet};

use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::util::bench::BenchGroup;
use aiinfn::workflow::{parse_workflow, Dag};

fn workflow_json(samples: usize) -> (String, Vec<String>) {
    let names: Vec<String> = (0..samples).map(|i| format!("s{i}")).collect();
    let reports: Vec<String> = names.iter().map(|n| format!("\"report/{n}.json\"")).collect();
    let wf = format!(
        r#"{{
  "rules": [
    {{"name": "preprocess", "input": ["raw/{{s}}.dat"], "output": ["clean/{{s}}.dat"],
     "resources": {{"cpu": 4000}}, "duration": 120}},
    {{"name": "train", "input": ["clean/{{s}}.dat"], "output": ["model/{{s}}.bin"],
     "resources": {{"cpu": 4000, "nvidia.com/mig-1g.5gb": 1}}, "duration": 900}},
    {{"name": "evaluate", "input": ["model/{{s}}.bin"], "output": ["report/{{s}}.json"],
     "resources": {{"cpu": 2000, "nvidia.com/mig-1g.5gb": 1}}, "duration": 180}},
    {{"name": "summary", "input": [{reports}], "output": ["summary.md"],
     "resources": {{"cpu": 1000}}, "duration": 30}}
  ],
  "targets": ["summary.md"]
}}"#,
        reports = reports.join(", ")
    );
    (wf, names)
}

/// Execute the DAG on the platform; returns makespan.
fn run_on_platform(samples: usize) -> f64 {
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    let (wf, names) = workflow_json(samples);
    let mut available: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
    let spec = parse_workflow(&wf).unwrap();
    let dag = Dag::build(&spec, &available).unwrap();

    let mut done: HashSet<usize> = HashSet::new();
    let mut submitted: HashMap<usize, String> = HashMap::new();
    let t0 = p.now();
    while done.len() < dag.jobs.len() {
        for j in dag.ready(&available, &done) {
            if submitted.contains_key(&j) {
                continue;
            }
            let job = &dag.jobs[j];
            let wl = p
                .submit_batch("wf-user", "wf-proj", job.resources.clone(), job.duration, PriorityClass::BatchHigh, false)
                .unwrap();
            submitted.insert(j, wl);
        }
        p.run_for(30.0, 10.0);
        for (j, wl) in submitted.clone() {
            if !done.contains(&j) && p.workload_state(&wl) == Some(WorkloadState::Finished) {
                done.insert(j);
                for out in &dag.jobs[j].outputs {
                    available.insert(out.clone());
                }
            }
        }
        assert!(p.now() - t0 < 48.0 * 3600.0, "workflow stalled");
    }
    p.now() - t0
}

fn main() {
    let mut g = BenchGroup::new("E5-workflow-dag");

    println!("\n| samples | jobs | sequential (s) | critical path (s) | platform makespan (s) | speedup |");
    println!("|---|---|---|---|---|---|");
    for samples in [2usize, 4, 8] {
        let (wf, names) = workflow_json(samples);
        let existing: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
        let dag = Dag::build(&parse_workflow(&wf).unwrap(), &existing).unwrap();
        let makespan = run_on_platform(samples);
        let speedup = dag.total_work() / makespan;
        println!(
            "| {} | {} | {:.0} | {:.0} | {:.0} | {:.2}× |",
            samples,
            dag.jobs.len(),
            dag.total_work(),
            dag.critical_path(),
            makespan,
            speedup
        );
        g.record_value(&format!("makespan-{samples}-samples"), makespan, "s");
        // dependencies honoured ⇒ makespan ≥ critical path; parallel fan-out
        // ⇒ decisively better than sequential for N ≥ 4
        assert!(makespan >= dag.critical_path() * 0.99);
        if samples >= 4 {
            assert!(speedup > 1.5, "fan-out must parallelize: {speedup}");
        }
    }

    // DAG resolution throughput (controller hot path)
    let (wf, names) = workflow_json(32);
    let spec = parse_workflow(&wf).unwrap();
    let existing: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
    g.bench_elements("dag-build-32-samples", 32 * 3 + 1, || {
        aiinfn::util::bench::black_box(Dag::build(&spec, &existing).unwrap());
    });
    println!("\nE5 workflow checks PASSED");
}
