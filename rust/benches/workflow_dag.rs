//! E5 — Snakemake workflows (§3): "explicit handling of job dependencies
//! and reproducible workflows ... job dependencies are managed by a
//! dedicated controller."
//!
//! Builds a fan-out pipeline (preprocess → train×N → evaluate → summary),
//! runs it through the platform controller, and compares makespan against
//! the sequential baseline and the critical-path bound. Also measures DAG
//! resolution throughput.
//!
//! The second half drives the *federated workflow engine* end to end: a
//! `WorkflowRun` whose training shards are pinned at three federation
//! sites, realized entirely by the workflow reconciler (gang admission,
//! data-locality placement, InterLink offload with stage-in/stage-out).
//! Emits `BENCH_workflow.json` (makespan, bytes moved, gang-admission
//! latency); CI uploads it and diffs against the committed
//! `bench-baselines/BENCH_workflow.json` (informational).

use std::collections::{HashMap, HashSet};

use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::workflow::{RunPhase, StageSpec, LOCAL_SITE};
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::util::bench::BenchGroup;
use aiinfn::util::json::Json;
use aiinfn::workflow::{parse_workflow, Dag};

const GB: u64 = 1 << 30;

fn workflow_json(samples: usize) -> (String, Vec<String>) {
    let names: Vec<String> = (0..samples).map(|i| format!("s{i}")).collect();
    let reports: Vec<String> = names.iter().map(|n| format!("\"report/{n}.json\"")).collect();
    let wf = format!(
        r#"{{
  "rules": [
    {{"name": "preprocess", "input": ["raw/{{s}}.dat"], "output": ["clean/{{s}}.dat"],
     "resources": {{"cpu": 4000}}, "duration": 120}},
    {{"name": "train", "input": ["clean/{{s}}.dat"], "output": ["model/{{s}}.bin"],
     "resources": {{"cpu": 4000, "nvidia.com/mig-1g.5gb": 1}}, "duration": 900}},
    {{"name": "evaluate", "input": ["model/{{s}}.bin"], "output": ["report/{{s}}.json"],
     "resources": {{"cpu": 2000, "nvidia.com/mig-1g.5gb": 1}}, "duration": 180}},
    {{"name": "summary", "input": [{reports}], "output": ["summary.md"],
     "resources": {{"cpu": 1000}}, "duration": 30}}
  ],
  "targets": ["summary.md"]
}}"#,
        reports = reports.join(", ")
    );
    (wf, names)
}

/// Execute the DAG on the platform; returns makespan.
fn run_on_platform(samples: usize) -> f64 {
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    let (wf, names) = workflow_json(samples);
    let mut available: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
    let spec = parse_workflow(&wf).unwrap();
    let dag = Dag::build(&spec, &available).unwrap();

    let mut done: HashSet<usize> = HashSet::new();
    let mut submitted: HashMap<usize, String> = HashMap::new();
    let t0 = p.now();
    while done.len() < dag.jobs.len() {
        for j in dag.ready(&available, &done) {
            if submitted.contains_key(&j) {
                continue;
            }
            let job = &dag.jobs[j];
            let wl = p
                .submit_batch("wf-user", "wf-proj", job.resources.clone(), job.duration, PriorityClass::BatchHigh, false)
                .unwrap();
            submitted.insert(j, wl);
        }
        p.run_for(30.0, 10.0);
        for (j, wl) in submitted.clone() {
            if !done.contains(&j) && p.workload_state(&wl) == Some(WorkloadState::Finished) {
                done.insert(j);
                for out in &dag.jobs[j].outputs {
                    available.insert(out.clone());
                }
            }
        }
        assert!(p.now() - t0 < 48.0 * 3600.0, "workflow stalled");
    }
    p.now() - t0
}

fn main() {
    let mut g = BenchGroup::new("E5-workflow-dag");

    println!("\n| samples | jobs | sequential (s) | critical path (s) | platform makespan (s) | speedup |");
    println!("|---|---|---|---|---|---|");
    for samples in [2usize, 4, 8] {
        let (wf, names) = workflow_json(samples);
        let existing: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
        let dag = Dag::build(&parse_workflow(&wf).unwrap(), &existing).unwrap();
        let makespan = run_on_platform(samples);
        let speedup = dag.total_work() / makespan;
        println!(
            "| {} | {} | {:.0} | {:.0} | {:.0} | {:.2}× |",
            samples,
            dag.jobs.len(),
            dag.total_work(),
            dag.critical_path(),
            makespan,
            speedup
        );
        g.record_value(&format!("makespan-{samples}-samples"), makespan, "s");
        // dependencies honoured ⇒ makespan ≥ critical path; parallel fan-out
        // ⇒ decisively better than sequential for N ≥ 4
        assert!(makespan >= dag.critical_path() * 0.99);
        if samples >= 4 {
            assert!(speedup > 1.5, "fan-out must parallelize: {speedup}");
        }
    }

    // DAG resolution throughput (controller hot path)
    let (wf, names) = workflow_json(32);
    let spec = parse_workflow(&wf).unwrap();
    let existing: HashSet<String> = names.iter().map(|n| format!("raw/{n}.dat")).collect();
    g.bench_elements("dag-build-32-samples", 32 * 3 + 1, || {
        aiinfn::util::bench::black_box(Dag::build(&spec, &existing).unwrap());
    });

    federated_engine_bench(&mut g);
    println!("\nE5 workflow checks PASSED");
}

fn stage(
    name: &str,
    cpu_millis: i64,
    pods: u32,
    duration: f64,
    inputs: &[&str],
    outputs: &[(&str, u64)],
    offloadable: bool,
) -> StageSpec {
    StageSpec {
        name: name.to_string(),
        requests: ResourceVec::cpu_millis(cpu_millis).with(MEMORY, 4 << 30),
        pods,
        duration,
        inputs: inputs.iter().map(|s| s.to_string()).collect(),
        outputs: outputs.iter().map(|(n, s)| (n.to_string(), *s)).collect(),
        offloadable,
    }
}

/// The full engine across three federated sites: training shards pinned at
/// INFN-T1 / ReCaS-Bari / CINECA-Leonardo pull their stages remote, the
/// shared calibration set stages in at each site, models stage back out,
/// and merge/publish run locally on the staged-back outputs.
fn federated_engine_bench(g: &mut BenchGroup) {
    let fast = std::env::var("AIINFN_BENCH_FAST").is_ok();
    let scale = if fast { 1.0 } else { 4.0 };
    let sites = ["INFN-T1", "ReCaS-Bari", "CINECA-Leonardo"];

    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    p.create_dataset("bench-calib", "user001", 2 * GB, vec![LOCAL_SITE.into()]).unwrap();
    let mut stages = vec![stage(
        "prep",
        4000,
        2,
        120.0 * scale,
        &["bench-calib"],
        &[("bench-clean", GB)],
        false,
    )];
    let mut models: Vec<String> = Vec::new();
    for (i, site) in sites.iter().enumerate() {
        let shard = format!("bench-shard-{i}");
        p.create_dataset(&shard, "user001", 80 * GB, vec![site.to_string()]).unwrap();
        let model = format!("bench-model-{i}");
        stages.push(stage(
            &format!("train-{i}"),
            8000,
            2,
            600.0 * scale,
            &[&shard, "bench-calib"],
            &[(&model, 4 * GB)],
            true,
        ));
        models.push(model);
    }
    let merge_inputs: Vec<&str> =
        models.iter().map(String::as_str).chain(std::iter::once("bench-clean")).collect();
    stages.push(stage(
        "merge",
        4000,
        1,
        180.0 * scale,
        &merge_inputs,
        &[("bench-merged", 2 * GB)],
        true,
    ));
    stages.push(stage(
        "publish",
        2000,
        1,
        60.0 * scale,
        &["bench-merged"],
        &[("bench-bundle", GB / 4)],
        false,
    ));
    let n_stages = stages.len();
    p.create_workflow_run(
        "bench-fed",
        "user001",
        "project01",
        PriorityClass::Batch,
        "workflow",
        stages,
    )
    .unwrap();

    const TICK: f64 = 15.0;
    let horizon = 24.0 * 3600.0;
    let t0 = p.now();
    while p.workflow_run("bench-fed").unwrap().phase != RunPhase::Succeeded {
        assert!(p.now() - t0 < horizon, "federated workflow stalled");
        p.run_for(TICK, TICK);
    }
    let makespan = p.now() - t0;

    let run = p.workflow_run("bench-fed").unwrap();
    let m = p.metrics();
    assert_eq!(m.workflow_stages_completed, n_stages as u64);
    assert!(m.workflow_offloaded_stages >= sites.len() as u64, "every train must offload");
    assert!(m.workflow_bytes_staged > 0);
    assert!(m.workflow_gangs_bound >= n_stages as u64);
    let gang_latency = m.workflow_gang_wait_total / m.workflow_gangs_bound as f64;
    let bytes_moved = run.bytes_staged;

    g.record_value("federated-makespan", makespan, "s");
    g.record_value("federated-bytes-moved-gb", bytes_moved as f64 / GB as f64, "GB");
    g.record_value("federated-gang-admission-latency", gang_latency, "s");

    let out = Json::obj(vec![
        ("stages", Json::num(n_stages as f64)),
        ("federated_sites", Json::num(sites.len() as f64)),
        ("tick_seconds", Json::num(TICK)),
        ("makespan_seconds", Json::num(makespan)),
        ("bytes_moved", Json::num(bytes_moved as f64)),
        ("bytes_moved_gb", Json::num(bytes_moved as f64 / GB as f64)),
        ("offloaded_stages", Json::num(m.workflow_offloaded_stages as f64)),
        ("gangs_bound", Json::num(m.workflow_gangs_bound as f64)),
        ("gang_admission_latency_seconds", Json::num(gang_latency)),
        ("stage_retries", Json::num(m.workflow_stage_retries as f64)),
    ]);
    std::fs::write("BENCH_workflow.json", out.to_pretty()).expect("write BENCH_workflow.json");
    println!("wrote BENCH_workflow.json");
    println!(
        "federated engine: {n_stages} stages over {} sites in {makespan:.0}s \
         ({:.1} GB moved, gang latency {gang_latency:.1}s)",
        sites.len(),
        bytes_moved as f64 / GB as f64
    );
}
