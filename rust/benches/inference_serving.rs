//! Inference-serving benchmark at the 1 000-node regime: eight
//! `InferenceServer`s (six CPU-sized, two MIG-slice-sized on shared
//! A100s) under seeded diurnal + burst traffic, driven through the full
//! reconciler stack — admission via the zero-nominal serving cohort queue,
//! scheduling, demand-driven MIG repartitioning, the
//! least-outstanding-requests balancer, and the latency-aware autoscaler.
//!
//! Measures the *simulated* serving quality (latency p50/p95/p99 and
//! sustained QPS over the horizon, straight from the balancer's
//! histograms) and the *wall-clock* control-plane cost of running it
//! (ticks/sec at 1k nodes with serving live, arrivals pumped per wall
//! second). Emits `BENCH_serving.json`; CI uploads it and diffs against
//! the committed `bench-baselines/BENCH_serving.json` (informational).

use std::time::Instant;

use aiinfn::gpu::GpuModel;
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::serve::ServingSpec;
use aiinfn::sim::traffic::{TrafficPattern, TrafficPlan};
use aiinfn::util::bench::BenchGroup;
use aiinfn::util::json::Json;
use aiinfn::util::stats::Histogram;

const NODES: usize = 1_000;
const GPU_NODES: usize = 8;
const SERVERS: usize = 8;
const TICK: f64 = 15.0;

fn spec(name: &str, mig: bool) -> ServingSpec {
    let mut requests = ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30);
    if mig {
        requests = requests.with("nvidia.com/mig-1g.5gb", 1);
    }
    ServingSpec {
        name: name.to_string(),
        user: "user001".to_string(),
        project: "project01".to_string(),
        model: if mig { "deepmet-gpu".to_string() } else { "deepmet".to_string() },
        requests,
        min_replicas: 0,
        max_replicas: 6,
        latency_slo: 0.5,
        max_batch: 8,
        batch_window: 0.02,
        service_time: 0.08, // 100 req/s per saturated replica
        queue_depth: 256,
        queue: "serving".to_string(),
    }
}

fn main() {
    let fast = std::env::var("AIINFN_BENCH_FAST").is_ok();
    let horizon: f64 = if fast { 1_800.0 } else { 7_200.0 };

    // 1 000-node inventory: 992 CPU servers plus 8 dual-A100 servers the
    // MIG-sized serving replicas land on.
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..NODES)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("srv-{i:04}");
            s.cpu_cores = 64;
            s.memory_gb = 256;
            s.nvme_tb = 4;
            s.gpus =
                if i < GPU_NODES { vec![GpuModel::A100_40GB; 2] } else { Vec::new() };
            s
        })
        .collect();
    cfg.federation_enabled = false;
    let mut p = Platform::bootstrap(cfg).unwrap();

    // Eight servers under diurnal baselines with seeded Poisson bursts.
    let baselines: Vec<TrafficPattern> = (0..SERVERS)
        .map(|i| TrafficPattern {
            diurnal_amplitude: 0.4,
            peak_at: 43_200.0,
            ..TrafficPattern::flat(&format!("serve-{i}"), 15.0 + 5.0 * i as f64)
        })
        .collect();
    let plan = TrafficPlan { seed: 42, horizon, bursts_per_hour: 1.0, ..Default::default() };
    p.set_traffic(plan.generate(baselines));
    for i in 0..SERVERS {
        p.create_inference_server(spec(&format!("serve-{i}"), i >= SERVERS - 2)).unwrap();
    }

    // Drive the whole horizon through the reconciler stack, timed.
    let ticks = (horizon / TICK).round() as u64;
    let t = Instant::now();
    p.run_for(horizon, TICK);
    let wall = t.elapsed().as_secs_f64();

    // Aggregate the balancer's latency histograms across the fleet.
    let mut latency = Histogram::latency();
    let mut total = 0u64;
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut in_flight = 0u64;
    for name in p.inference_server_names() {
        let s = p.serving_state(&name).unwrap();
        latency.merge(&s.latency);
        total += s.total_requests;
        completed += s.completed_requests;
        failed += s.failed_requests;
        in_flight += s.queued();
    }
    assert_eq!(total, completed + failed + in_flight, "request accounting must balance");
    assert!(completed > 0, "the fleet must serve requests");

    let p50 = latency.percentile_checked(50.0).unwrap_or(0.0);
    let p95 = latency.percentile_checked(95.0).unwrap_or(0.0);
    let p99 = latency.percentile_checked(99.0).unwrap_or(0.0);
    let sustained_qps = completed as f64 / horizon;
    let ticks_per_sec = ticks as f64 / wall;
    let wall_req_per_sec = completed as f64 / wall;
    let m = p.metrics();

    let mut g = BenchGroup::new("inference_serving");
    g.record_value("latency_p50_seconds", p50, "s");
    g.record_value("latency_p95_seconds", p95, "s");
    g.record_value("latency_p99_seconds", p99, "s");
    g.record_value("sustained_qps_sim", sustained_qps, "req/s");
    g.record_value("ticks_per_sec_1k_nodes", ticks_per_sec, "ticks/s");
    g.record_value("requests_per_wall_sec", wall_req_per_sec, "req/s");

    let out = Json::obj(vec![
        ("nodes", Json::num(NODES as f64)),
        ("servers", Json::num(SERVERS as f64)),
        ("horizon_seconds", Json::num(horizon)),
        ("tick_seconds", Json::num(TICK)),
        ("total_requests", Json::num(total as f64)),
        ("completed_requests", Json::num(completed as f64)),
        ("failed_requests", Json::num(failed as f64)),
        ("latency_p50_seconds", Json::num(p50)),
        ("latency_p95_seconds", Json::num(p95)),
        ("latency_p99_seconds", Json::num(p99)),
        ("sustained_qps_sim", Json::num(sustained_qps)),
        ("ticks_per_sec_1k_nodes", Json::num(ticks_per_sec)),
        ("requests_per_wall_sec", Json::num(wall_req_per_sec)),
        ("scale_events", Json::num(m.serving_scale_events as f64)),
        ("cold_starts", Json::num(m.serving_cold_starts as f64)),
    ]);
    std::fs::write("BENCH_serving.json", out.to_pretty()).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");
    println!(
        "serving: {completed} completed / {failed} failed of {total} \
         (p50 {p50:.3}s p95 {p95:.3}s p99 {p99:.3}s, {sustained_qps:.1} req/s sustained, \
         {ticks_per_sec:.1} ticks/s at {NODES} nodes)"
    );
}
