//! API-verb throughput microbenchmark: how many control-plane write/read
//! operations per second the apply/reconcile front door sustains —
//! `create`, `apply` (update leg), `patch` (strategic merge), `get`,
//! `list` with a selector, and `watch` catch-up reads — plus the
//! 5 000-object scale regime with an **in-run before/after harness**: the
//! indexed list/watch read path measured against the pre-index baseline
//! (serialize-every-object selector filtering, scan-every-kind watch
//! catch-up) in the same process, so the speedup is apples-to-apples.
//!
//! Emits the standard `BENCH\t…` rows plus a machine-readable
//! `BENCH_api.json` with median ops/sec per verb and the 5k-scale
//! `*_5k` / `*_baseline_*` / `*_speedup_5k` fields, so CI and
//! EXPERIMENTS.md tables can track regressions on the API hot path.

mod scale_reads;

use aiinfn::api::{ApiObject, ApiServer, BatchJobResource, ResourceKind, Selector};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::{default_config_path, PlatformConfig};
use aiinfn::queue::kueue::PriorityClass;
use aiinfn::util::bench::{black_box, BenchGroup};
use aiinfn::util::json::Json;

fn request(user: &str) -> ApiObject {
    ApiObject::BatchJob(BatchJobResource::request(
        user,
        "project00",
        ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
        600.0,
        PriorityClass::Batch,
        false,
    ))
}

fn main() {
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut api = ApiServer::bootstrap(cfg).unwrap();
    let token = api.login("user001").unwrap();

    // seed a populated control plane: 100 jobs, some already realized as
    // pods, so get/list measure against realistic object counts
    let mut names = Vec::new();
    for _ in 0..100 {
        names.push(api.create(&token, &request("user001")).unwrap().name().to_string());
    }
    api.run_for(300.0, 30.0);

    let mut g = BenchGroup::new("api_verbs");

    let target = names[0].clone();
    let get_ops = {
        let r = g.bench("get_batch_job", || {
            black_box(api.get(&token, ResourceKind::BatchJob, &target).unwrap());
        });
        r.per_sec()
    };

    let selector = Selector::labels("app=batch").unwrap();
    let list_ops = {
        let r = g.bench("list_pods_label_selector", || {
            black_box(api.list(&token, ResourceKind::Pod, &selector).unwrap());
        });
        r.per_sec()
    };

    let watch_from = api.last_rv().saturating_sub(200);
    let watch_ops = {
        let r = g.bench("watch_catchup_200", || {
            black_box(api.watch(&token, ResourceKind::Pod, watch_from).unwrap());
        });
        r.per_sec()
    };

    let create_ops = {
        let r = g.bench("create_batch_job", || {
            black_box(api.create(&token, &request("user001")).unwrap());
        });
        r.per_sec()
    };

    // apply's update leg: flip a mutable spec field unconditionally
    let mut desired = api
        .get(&token, ResourceKind::BatchJob, &target)
        .unwrap()
        .as_batch_job()
        .unwrap()
        .clone();
    desired.metadata.resource_version = 0;
    let apply_ops = {
        let r = g.bench("apply_update", || {
            desired.offloadable = !desired.offloadable;
            black_box(api.apply(&token, &ApiObject::BatchJob(desired.clone())).unwrap());
        });
        r.per_sec()
    };

    let patch_on = Json::parse(r#"{"spec":{"offloadable":true}}"#).unwrap();
    let patch_ops = {
        let r = g.bench("patch_strategic_merge", || {
            black_box(
                api.patch(&token, ResourceKind::BatchJob, &target, &patch_on).unwrap(),
            );
        });
        r.per_sec()
    };

    // ----------------------------------------------------- the 5k regime
    // Grow the control plane to ~5 000 API objects of the listed kind
    // (plus their Workload shadows), with a 1% "hot" labeled subset — the
    // selective-query shape the inverted index exists for — and measure
    // the indexed read paths against their in-run baselines (shared
    // harness with control_plane_scale).
    scale_reads::populate(&mut api, &token, "user001", 5_000, 50);
    let reads = scale_reads::bench_reads(&mut g, &api, &token);

    let out = Json::obj(vec![
        ("get_ops_per_sec", Json::num(get_ops)),
        ("list_ops_per_sec", Json::num(list_ops)),
        ("watch_ops_per_sec", Json::num(watch_ops)),
        ("create_ops_per_sec", Json::num(create_ops)),
        ("apply_ops_per_sec", Json::num(apply_ops)),
        ("patch_ops_per_sec", Json::num(patch_ops)),
        ("api_objects_at_scale", Json::num(reads.objects as f64)),
        ("list_ops_per_sec_5k", Json::num(reads.list_indexed)),
        ("list_baseline_ops_per_sec_5k", Json::num(reads.list_baseline)),
        ("list_speedup_5k", Json::num(reads.list_speedup())),
        ("watch_ops_per_sec_5k", Json::num(reads.watch_indexed)),
        ("watch_baseline_ops_per_sec_5k", Json::num(reads.watch_baseline)),
        ("watch_speedup_5k", Json::num(reads.watch_speedup())),
    ]);
    std::fs::write("BENCH_api.json", out.to_pretty()).expect("write BENCH_api.json");
    println!("wrote BENCH_api.json");
}
