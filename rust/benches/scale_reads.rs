//! Shared 5k-object read harness for `api_verbs` and
//! `control_plane_scale`: grow the control plane to N batch jobs with a
//! hot-labeled subset, then measure the indexed list/watch read paths
//! against their pre-change baselines **in the same run** (brute-force
//! serialize-and-filter list, scan-every-kind watch catch-up), asserting
//! the fast and slow paths agree before the numbers are reported. One
//! implementation, two bench binaries — the selector shape and baseline
//! fairness cannot drift apart between `BENCH_api.json` and
//! `BENCH_scale.json`.

use aiinfn::api::{ApiObject, ApiServer, BatchJobResource, ResourceKind, Selector};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::queue::kueue::PriorityClass;
use aiinfn::util::bench::{black_box, BenchGroup};

/// Ops/sec for the four measured read paths at scale.
pub struct ReadNumbers {
    /// Objects of the listed kind present during measurement.
    pub objects: usize,
    pub list_indexed: f64,
    pub list_baseline: f64,
    pub watch_indexed: f64,
    pub watch_baseline: f64,
}

impl ReadNumbers {
    pub fn list_speedup(&self) -> f64 {
        self.list_indexed / self.list_baseline.max(f64::MIN_POSITIVE)
    }

    pub fn watch_speedup(&self) -> f64 {
        self.watch_indexed / self.watch_baseline.max(f64::MIN_POSITIVE)
    }
}

fn job_request(user: &str, labels: &[(&str, &str)]) -> ApiObject {
    let mut obj = ApiObject::BatchJob(BatchJobResource::request(
        user,
        "project00",
        ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
        600.0,
        PriorityClass::Batch,
        false,
    ));
    for (k, v) in labels {
        obj.metadata_mut().labels.insert(k.to_string(), v.to_string());
    }
    obj
}

/// Grow the plane to at least `total` BatchJobs, then add `hot_count`
/// jobs labeled `bench/hot=yes` unconditionally (earlier benches may
/// already have grown the plane past `total` plain jobs). Returns the
/// resulting object count.
pub fn populate(api: &mut ApiServer, token: &str, user: &str, total: usize, hot_count: usize) -> usize {
    let existing = api.list(token, ResourceKind::BatchJob, &Selector::all()).unwrap().len();
    for _ in existing..total.saturating_sub(hot_count) {
        api.create(token, &job_request(user, &[])).unwrap();
    }
    for _ in 0..hot_count {
        api.create(token, &job_request(user, &[("bench/hot", "yes")])).unwrap();
    }
    api.list(token, ResourceKind::BatchJob, &Selector::all()).unwrap().len()
}

/// Measure hot-label list and watch catch-up, indexed vs. the pre-index
/// baselines, asserting both paths agree. Bench row names are stable
/// across the two callers; the group name distinguishes them.
pub fn bench_reads(g: &mut BenchGroup, api: &ApiServer, token: &str) -> ReadNumbers {
    let hot = Selector::labels("bench/hot=yes").unwrap();

    let list_indexed = g
        .bench("list_5k_label_indexed", || {
            black_box(api.list(token, ResourceKind::BatchJob, &hot).unwrap());
        })
        .per_sec();
    // pre-index baseline, same run: build every view, serialize it, and
    // evaluate the selector on the JSON — exactly the former read path
    let list_baseline = g
        .bench("list_5k_label_bruteforce", || {
            let all = api.list(token, ResourceKind::BatchJob, &Selector::all()).unwrap();
            let matched: Vec<ApiObject> =
                all.into_iter().filter(|o| hot.matches(&o.to_json())).collect();
            black_box(matched);
        })
        .per_sec();

    let watch_from = api.last_rv().saturating_sub(200);
    let watch_indexed = g
        .bench("watch_5k_catchup_indexed", || {
            black_box(api.watch(token, ResourceKind::BatchJob, watch_from).unwrap());
        })
        .per_sec();
    let watch_baseline = g
        .bench("watch_5k_catchup_scan", || {
            black_box(api.watch_scan_baseline(ResourceKind::BatchJob, watch_from));
        })
        .per_sec();

    // the fast and slow paths must agree before their numbers mean anything
    let a = api.list(token, ResourceKind::BatchJob, &hot).unwrap();
    let b: Vec<ApiObject> = api
        .list(token, ResourceKind::BatchJob, &Selector::all())
        .unwrap()
        .into_iter()
        .filter(|o| hot.matches(&o.to_json()))
        .collect();
    assert_eq!(a, b, "indexed list must equal brute force");
    assert_eq!(
        api.watch(token, ResourceKind::BatchJob, watch_from).unwrap(),
        api.watch_scan_baseline(ResourceKind::BatchJob, watch_from),
        "sharded watch must equal the scan baseline"
    );

    ReadNumbers {
        objects: api.list(token, ResourceKind::BatchJob, &Selector::all()).unwrap().len(),
        list_indexed,
        list_baseline,
        watch_indexed,
        watch_baseline,
    }
}
