//! E4 — offloading scalability (§3): "Successful scalability tests have
//! validated this architecture by orchestrating workloads across four
//! different sites using heterogeneous schedulers (HTCondor and SLURM) and
//! backends (Podman)."
//!
//! Sweeps the number of federation sites 0→4 on a fixed 300-job campaign
//! and reports makespan + throughput — the "who wins / how it scales"
//! series. Also measures the raw InterLink protocol round-trip.

use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::offload::htcondor::HtcondorPool;
use aiinfn::offload::vk::VirtualKubelet;
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::util::bench::BenchGroup;

const N_JOBS: usize = 300;

/// Run the campaign with the first `n_sites` federation sites enabled.
fn campaign(n_sites: usize) -> (f64, u64, u64) {
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    cfg.federation_enabled = n_sites > 0;
    let mut p = Platform::bootstrap(cfg).unwrap();
    // trim the federation to the first n sites
    p.truncate_federation(n_sites);
    let mut wls = Vec::new();
    for i in 0..N_JOBS {
        wls.push(
            p.submit_batch(
                &format!("user{:03}", i % 78),
                &format!("project{:02}", i % 20),
                ResourceVec::cpu_millis(16_000).with(MEMORY, 24 << 30),
                600.0,
                PriorityClass::Batch,
                true,
            )
            .unwrap(),
        );
    }
    let t0 = p.now();
    loop {
        p.run_for(300.0, 15.0);
        let done = wls
            .iter()
            .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
            .count();
        if done == N_JOBS || p.now() - t0 > 7.0 * 24.0 * 3600.0 {
            break;
        }
    }
    (p.now() - t0, p.metrics().local_completions, p.metrics().remote_completions)
}

fn main() {
    let mut g = BenchGroup::new("E4-offload-scale");

    println!("\n| sites | makespan (h) | local done | remote done | throughput (jobs/h) |");
    println!("|---|---|---|---|---|");
    let mut makespans = Vec::new();
    for n_sites in [0usize, 1, 2, 3, 4] {
        let (makespan, local, remote) = campaign(n_sites);
        println!(
            "| {} | {:.2} | {} | {} | {:.1} |",
            n_sites,
            makespan / 3600.0,
            local,
            remote,
            N_JOBS as f64 / (makespan / 3600.0)
        );
        g.record_value(&format!("makespan-{n_sites}-sites"), makespan, "s");
        makespans.push(makespan);
        if n_sites == 4 {
            assert!(remote > 0, "4-site federation must absorb overflow");
        }
    }
    // scalability: 4 sites must beat local-only decisively
    let speedup = makespans[0] / makespans[4];
    g.record_value("speedup-4-sites-vs-local", speedup, "x");
    println!("\nspeedup with full federation: {speedup:.2}× over local-only");
    assert!(speedup > 1.5, "federation must speed the campaign up: {speedup}");
    // monotone non-increasing makespan (within 5% noise)
    for w in makespans.windows(2) {
        assert!(w[1] <= w[0] * 1.05, "adding a site must not slow things: {makespans:?}");
    }

    // raw InterLink wire round-trip (encode → sidecar → decode)
    let pool = HtcondorPool::new("bench", &[(4, 32, 192 << 30, 0)]);
    let mut vk = VirtualKubelet::new("vk-bench", "bench", Box::new(pool), "tok", 0.0);
    let spec = aiinfn::cluster::pod::PodSpec::new(
        "p0",
        ResourceVec::cpu_millis(1000),
        aiinfn::cluster::pod::Payload::Sleep { duration: 60.0 },
    );
    vk.create_pod(&spec, 60.0, 0.0).unwrap();
    let mut t = 1.0;
    g.bench("interlink-status-roundtrip", || {
        t += 0.001;
        aiinfn::util::bench::black_box(vk.sync(t));
    });
    println!("\nE4 offload-scale checks PASSED");
}
