//! E6 — encrypted deduplicating backup (§2): "The platform file system is
//! subject to regular encrypted backup ... using the BorgBackup package to
//! ensure data deduplication."
//!
//! Simulates a week of nightly snapshots over synthetic user homes with
//! realistic daily churn and reports the table Borg admins watch: logical
//! vs stored size, dedup ratio, per-snapshot transfer. Also measures raw
//! chunking and seal (compress+encrypt) throughput.

use aiinfn::storage::backup::{chunk_boundaries, BackupRepo, ChunkerParams};
use aiinfn::util::bench::BenchGroup;
use aiinfn::util::fmt_bytes;
use aiinfn::util::rng::Rng;

/// Synthetic home directories: notebooks (text-ish), datasets (binary),
/// checkpoints (float-ish). ~`users` × 3 files.
fn make_homes(rng: &mut Rng, users: usize) -> Vec<(String, Vec<u8>)> {
    let mut files = Vec::new();
    for u in 0..users {
        let nb: Vec<u8> = (0..rng.range_i64(20_000, 60_000)).map(|_| (rng.below(60) + 32) as u8).collect();
        let ds: Vec<u8> = (0..rng.range_i64(200_000, 500_000)).map(|_| rng.below(256) as u8).collect();
        let ck: Vec<u8> = (0..rng.range_i64(100_000, 300_000)).map(|_| (rng.below(16) * 16) as u8).collect();
        files.push((format!("home-user{u:03}/analysis.ipynb"), nb));
        files.push((format!("home-user{u:03}/data.parquet"), ds));
        files.push((format!("home-user{u:03}/model.ckpt"), ck));
    }
    files
}

/// Apply daily churn: a few % of each file region rewritten, some files grow.
fn churn(rng: &mut Rng, files: &mut [(String, Vec<u8>)]) {
    for (_, data) in files.iter_mut() {
        if rng.bool(0.6) {
            // edit a contiguous region (2-8%)
            let frac = rng.range_f64(0.02, 0.08);
            let len = ((data.len() as f64) * frac) as usize;
            if len > 0 && data.len() > len {
                let start = rng.below((data.len() - len) as u64) as usize;
                for b in &mut data[start..start + len] {
                    *b = rng.below(256) as u8;
                }
            }
        }
        if rng.bool(0.3) {
            // append (notebook grows)
            let extra: Vec<u8> = (0..rng.range_i64(1000, 10_000)).map(|_| (rng.below(60) + 32) as u8).collect();
            data.extend(extra);
        }
    }
}

fn main() {
    let mut g = BenchGroup::new("E6-backup-dedup");
    let mut rng = Rng::new(2024);
    let mut files = make_homes(&mut rng, 20);
    let mut repo = BackupRepo::new("ai-infn-backup-passphrase");

    println!("\n| night | logical | transferred | stored (cum.) | dedup ratio |");
    println!("|---|---|---|---|---|");
    let mut transfers = Vec::new();
    for night in 0..7 {
        if night > 0 {
            churn(&mut rng, &mut files);
        }
        let logical: u64 = files.iter().map(|(_, d)| d.len() as u64).sum();
        let (_, transferred) = repo.create_snapshot(
            &format!("night-{night}"),
            night as f64 * 86400.0,
            files.iter().map(|(p, d)| (p.as_str(), d.as_slice())),
        );
        let st = repo.stats();
        println!(
            "| {} | {} | {} | {} | {:.2}× |",
            night,
            fmt_bytes(logical),
            fmt_bytes(transferred),
            fmt_bytes(st.stored_bytes),
            st.dedup_ratio()
        );
        transfers.push(transferred);
    }
    let st = repo.stats();
    g.record_value("dedup-ratio-7-nights", st.dedup_ratio(), "x");
    g.record_value("compression-ratio", st.compression_ratio(), "x");
    g.record_value("stored-bytes", st.stored_bytes as f64, "B");

    // Borg's signature behaviour: incremental transfers ≪ full size
    let full = transfers[0] as f64;
    let incr = transfers[1..].iter().copied().sum::<u64>() as f64 / 6.0;
    println!("\nmean incremental transfer: {} ({:.1}% of initial)", fmt_bytes(incr as u64), 100.0 * incr / full);
    assert!(incr < 0.35 * full, "incrementals must dedup: {incr} vs {full}");
    assert!(st.dedup_ratio() > 3.0, "7 mostly-unchanged nights must dedup >3×: {:.2}", st.dedup_ratio());

    // restore integrity after pruning
    let reclaimed = repo.prune(3);
    let restored = repo.restore(repo.snapshots().len() - 1, "home-user000/analysis.ipynb").unwrap();
    assert_eq!(restored, files[0].1, "restore after prune must be byte-exact");
    g.record_value("prune-reclaimed", reclaimed as f64, "B");

    // raw engine throughput
    let blob: Vec<u8> = (0..4 << 20).map(|i| ((i * 2654435761u64 as usize) >> 16) as u8).collect();
    g.bench_elements("chunking-4MiB", blob.len() as u64, || {
        aiinfn::util::bench::black_box(chunk_boundaries(&blob, ChunkerParams::default()));
    });
    let small: Vec<(String, Vec<u8>)> = vec![("f".into(), blob.clone())];
    g.bench_elements("snapshot-4MiB-cold", blob.len() as u64, || {
        let mut r = BackupRepo::new("x");
        aiinfn::util::bench::black_box(
            r.create_snapshot("s", 0.0, small.iter().map(|(p, d)| (p.as_str(), d.as_slice()))),
        );
    });
    println!("\nE6 backup-dedup checks PASSED");
}
