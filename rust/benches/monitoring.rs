//! E9 — monitoring & accounting (§2): Prometheus + kube-eagle + DCGM +
//! custom storage exporters, Grafana dashboards, per-user accounting.
//!
//! Measures the monitoring pipeline at platform scale: scrape cost for the
//! 4-server fleet, TSDB ingest rate, query latencies, and generates the
//! accounting report for a simulated week.

use aiinfn::gpu::dcgm::DcgmSimulator;
use aiinfn::monitoring::exporters;
use aiinfn::monitoring::tsdb::{SeriesKey, Tsdb};
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::sim::clock::hours;
use aiinfn::sim::trace::{generate, ArrivalKind, TraceConfig};
use aiinfn::util::bench::BenchGroup;

fn main() {
    let mut g = BenchGroup::new("E9-monitoring");

    // raw TSDB ingest
    let mut db = Tsdb::new(3600.0 * 24.0);
    let keys: Vec<SeriesKey> = (0..100)
        .map(|i| SeriesKey::new("bench_metric", &[("node", &format!("n{}", i % 8)), ("idx", &i.to_string())]))
        .collect();
    let mut t = 0.0f64;
    g.bench_elements("tsdb-ingest-100-series", 100, || {
        t += 1.0;
        for k in &keys {
            db.ingest(k.clone(), t, t * 0.5);
        }
    });

    // query latency over a populated store
    let qk = keys[0].clone();
    g.bench("tsdb-rate-query", || {
        aiinfn::util::bench::black_box(db.rate(&qk, t - 600.0, t));
    });
    g.bench("tsdb-sum-by-node", || {
        aiinfn::util::bench::black_box(db.sum_by("bench_metric", "node", t));
    });

    // full-fleet scrape cost (nodes + 30 accelerators + storage)
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    let mut dcgm = DcgmSimulator::new(9);
    let mut db2 = Tsdb::new(3600.0);
    let mut ts = 0.0f64;
    g.bench("full-fleet-scrape", || {
        ts += 30.0;
        let st = p.cluster();
        exporters::scrape_nodes(&mut db2, &st, ts);
        exporters::scrape_gpus(&mut db2, &st, &mut dcgm, ts);
        exporters::scrape_pods(&mut db2, &st, ts);
    });
    println!("series after fleet scrapes: {}", db2.series_count());

    // a simulated week of operation → accounting report + dashboard render
    let horizon = hours(7.0 * 24.0);
    let trace = generate(&TraceConfig { seed: 5, ..Default::default() }, horizon);
    for a in trace.iter().filter(|a| a.kind == ArrivalKind::Batch) {
        let _ = p.submit_ml_training(&a.user, &a.project, a.duration * 5e12, a.gpu, false);
    }
    p.run_for(horizon, 300.0);
    g.record_value("week-samples-ingested", p.tsdb.samples_ingested() as f64, "samples");
    g.record_value("week-series", p.tsdb.series_count() as f64, "series");

    let report = p.usage_report();
    let text = report.render("E9 weekly accounting (top users)");
    println!("\n{text}");
    assert!(!report.by_user.is_empty(), "accounting must attribute usage");
    assert!(p.tsdb.samples_ingested() > 10_000);

    g.bench("accounting-report", || {
        aiinfn::util::bench::black_box(p.usage_report());
    });
    g.bench("dashboard-render", || {
        aiinfn::util::bench::black_box(aiinfn::monitoring::dashboard::overview(&p.tsdb, p.now(), hours(24.0)));
    });
    println!("E9 monitoring checks PASSED");
}
