//! E7 — the §2 motivation: the old VM-based model's "inefficient use of
//! accelerators ... and unsustainable administrative demands" vs the
//! cloud-native platform's dynamic allocation + MIG.
//!
//! Replays the same 2-week user trace against (a) the static-VM farm
//! (per-user GPU pinning, week-long leases, no queue) and (b) the AI_INFN
//! platform (Kueue + MIG + dynamic scheduling), and reports the comparison
//! the paper's §2 narrative implies: served fraction, accelerator
//! efficiency, peak concurrent users, admin interventions.

use aiinfn::baseline::StaticVmFarm;
use aiinfn::hub::profiles::default_catalogue;
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::sim::clock::hours;
use aiinfn::sim::trace::{generate, ArrivalKind, GpuDemand, TraceConfig};
use aiinfn::util::bench::BenchGroup;

fn main() {
    let mut g = BenchGroup::new("E7-vm-vs-k8s");
    let horizon = hours(14.0 * 24.0);
    let trace = generate(&TraceConfig { seed: 77, ..Default::default() }, horizon);
    let gpu_arrivals = trace.iter().filter(|a| a.gpu != GpuDemand::None).count();
    println!("\ntrace: {} arrivals over 2 weeks, {gpu_arrivals} wanting accelerators", trace.len());

    // ---------------- (a) static VM farm: the ML_INFN baseline ----------
    let mut farm = StaticVmFarm::new(20); // the paper's 20 NVIDIA GPUs
    let vm = farm.replay(&trace);

    // ---------------- (b) the AI_INFN platform --------------------------
    let cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let mut p = Platform::bootstrap(cfg).unwrap();
    let catalogue = default_catalogue();
    let mut ti = 0;
    let mut served = 0u64;
    let mut refused = 0u64;
    while p.now() < horizon {
        let until = (p.now() + 600.0).min(horizon);
        while ti < trace.len() && trace[ti].at <= until {
            let a = &trace[ti];
            ti += 1;
            if a.gpu == GpuDemand::None {
                continue;
            }
            match a.kind {
                ArrivalKind::Interactive => {
                    let prof = match a.gpu {
                        GpuDemand::MigSlice(1) => &catalogue[1],
                        GpuDemand::MigSlice(_) => &catalogue[2],
                        _ => &catalogue[4],
                    };
                    match p.spawn_session(&a.user, prof) {
                        Ok(_) => served += 1,
                        Err(_) => refused += 1, // user already active / queue full
                    }
                }
                ArrivalKind::Batch => {
                    // batch never refused: it queues (the whole point)
                    let _ = p.submit_ml_training(&a.user, &a.project, a.duration * 8e12, a.gpu, false);
                    served += 1;
                }
            }
        }
        p.run_for(until - p.now(), 120.0);
    }
    let report = p.usage_report();
    let k8s_used: f64 = report.by_user.values().map(|u| u.total_gpu_hours()).sum();
    // the platform never pins: hours *held* = hours actually allocated to
    // pods, i.e. its efficiency denominator equals its numerator up to the
    // idle-culler window. The VM farm's denominator is week-long leases.
    let fleet_hours = 20.0 * (horizon / 3600.0);
    // "admin ops" on the platform: MIG layouts are applied once at boot
    let k8s_admin_ops = 5; // one repartition per A100

    println!("\n| metric | static VM (ML_INFN) | AI_INFN platform |");
    println!("|---|---|---|");
    println!("| requests served | {} | {} |", vm.served, served);
    println!("| requests refused | {} ({:.0}%) | {} |", vm.refused, vm.refusal_rate() * 100.0, refused);
    println!("| peak concurrent GPU users | {} | {} (35 MIG + 14 whole) |", vm.peak_concurrent_users, 35 + 14);
    let vm_hours_per_req = vm.gpu_hours_held / vm.served.max(1) as f64;
    let k8s_hours_per_req = k8s_used / served.max(1) as f64;
    println!("| GPU-hours consumed (held) | {:.0} | {:.0} (MIG-equivalent; no pinning) |", vm.gpu_hours_held, k8s_used);
    println!(
        "| allocation efficiency (used/held) | {:.1}% | ~100% |",
        vm.efficiency() * 100.0
    );
    println!(
        "| GPU-hours per request served | {:.2} | {:.2} |",
        vm_hours_per_req, k8s_hours_per_req
    );
    println!(
        "| fleet GPU-hours tied up | {:.1}% | {:.1}% |",
        vm.gpu_hours_held / fleet_hours * 100.0,
        k8s_used / fleet_hours * 100.0
    );
    println!("| admin interventions | {} | {} |", vm.admin_ops, k8s_admin_ops);

    g.record_value("vm-allocation-efficiency", vm.efficiency() * 100.0, "%");
    g.record_value("vm-gpu-hours-per-request", vm_hours_per_req, "h");
    g.record_value("k8s-gpu-hours-per-request", k8s_hours_per_req, "h");
    g.record_value("vm-refusal-rate", vm.refusal_rate() * 100.0, "%");
    g.record_value("vm-admin-ops", vm.admin_ops as f64, "ops");
    g.record_value("k8s-admin-ops", k8s_admin_ops as f64, "ops");

    // The §2 claims, asserted as directional results:
    assert!(vm.refusal_rate() > 0.05, "static pinning must refuse users: {}", vm.refusal_rate());
    assert!(
        vm.efficiency() < 0.5,
        "static pinning must waste held GPU-hours: {}",
        vm.efficiency()
    );
    assert!(
        served > vm.served,
        "dynamic allocation must serve more requests on the same trace: {served} vs {}",
        vm.served
    );
    assert!(
        k8s_hours_per_req < 0.5 * vm_hours_per_req,
        "MIG sharing + no pinning must slash GPU-hours per request: {k8s_hours_per_req} vs {vm_hours_per_req}"
    );
    assert!(vm.admin_ops as f64 > 10.0 * k8s_admin_ops as f64, "admin load must drop");
    println!("\nE7 vm-vs-k8s checks PASSED");
}
