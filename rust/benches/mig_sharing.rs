//! E2 — MIG sharing: "a single physical GPU to serve up to seven users
//! simultaneously" (§2).
//!
//! Sweeps every valid A100 MIG layout and measures users served per GPU and
//! allocation ratio, then replays a 78-user session trace against (a) the
//! whole-GPU baseline and (b) the MIG-partitioned fleet, reporting how many
//! simultaneous users each configuration sustains.

use aiinfn::cluster::node::Node;
use aiinfn::cluster::pod::{Payload, PodSpec};
use aiinfn::cluster::resources::ResourceVec;
use aiinfn::cluster::scheduler::Scheduler;
use aiinfn::cluster::store::ClusterStore;
use aiinfn::gpu::mig::{enumerate_layouts, MigLayout};
use aiinfn::gpu::{GpuDevice, GpuModel};
use aiinfn::util::bench::BenchGroup;

/// How many 1-slice-equivalent user pods fit a node with one A100 in the
/// given layout, by actually scheduling pods.
fn users_served(layout: &MigLayout) -> usize {
    let gpu = GpuDevice::partitioned("g0", GpuModel::A100_40GB, layout.clone()).unwrap();
    let mut store = ClusterStore::new();
    store.add_node(Node::physical("n", 64, 512 << 30, 1 << 40, vec![gpu]), 0.0);
    let sched = Scheduler::default();
    let mut served = 0;
    // users request the *smallest* instance the layout offers (greedy share)
    let mut asks: Vec<String> = layout
        .instances
        .iter()
        .map(|p| p.resource_name())
        .collect();
    if asks.is_empty() {
        asks.push("nvidia.com/gpu".to_string());
    }
    for (i, ask) in asks.iter().enumerate() {
        let spec = PodSpec::new(
            format!("user-pod-{i}"),
            ResourceVec::cpu_millis(1000).with(ask, 1),
            Payload::Session { idle_after: 3600.0 },
        );
        store.create_pod(spec, 0.0);
    }
    let (placed, _) = sched.schedule_pending(&mut store, 0.0);
    served += placed.len();
    served
}

fn main() {
    let mut g = BenchGroup::new("E2-mig-sharing");

    println!("\n| A100 layout | instances | users served | compute slices used |");
    println!("|---|---|---|---|");
    let mut max_users = 0;
    for layout in enumerate_layouts(GpuModel::A100_40GB) {
        let users = users_served(&layout);
        let slices: u8 = layout.instances.iter().map(|p| p.compute_slices).sum();
        let label: Vec<String> = layout.instances.iter().map(|p| p.label()).collect();
        println!("| {} | {} | {} | {}/7 |", label.join("+"), layout.instances.len(), users, slices);
        assert_eq!(users, layout.instances.len(), "every instance must be schedulable");
        max_users = max_users.max(users);
    }
    // whole-GPU baseline
    let whole = users_served(&MigLayout::new(GpuModel::A100_40GB, vec![]).unwrap());
    println!("| (no MIG) | 1 | {whole} | 7/7 |");

    // the paper's headline claim
    assert_eq!(max_users, 7, "paper: up to seven users per A100");
    assert_eq!(whole, 1);
    g.record_value("max-users-per-a100", max_users as f64, "users");
    g.record_value("users-per-a100-no-mig", whole as f64, "users");
    g.record_value("sharing-gain", max_users as f64 / whole as f64, "x");

    // fleet-level: 78 users hitting the 5-A100 fleet (35 slices + 14 whole GPUs)
    let cfg = aiinfn::platform::PlatformConfig::load(&aiinfn::platform::default_config_path()).unwrap();
    let nodes = cfg.build_nodes().unwrap();
    let mut store = ClusterStore::new();
    for n in nodes {
        store.add_node(n, 0.0);
    }
    let sched = Scheduler::default();
    for i in 0..78 {
        let spec = PodSpec::new(
            format!("sess-{i}"),
            ResourceVec::cpu_millis(2000).with("nvidia.com/mig-1g.5gb", 1),
            Payload::Session { idle_after: 3600.0 },
        );
        store.create_pod(spec, 0.0);
    }
    let (placed, _) = sched.schedule_pending(&mut store, 0.0);
    println!("\nfleet check: {} of 78 registered users hold a MIG slice concurrently (35 slices exist)", placed.len());
    assert_eq!(placed.len(), 35);
    g.record_value("fleet-concurrent-mig-users", placed.len() as f64, "users");

    // scheduling throughput with MIG resources in play
    g.bench_elements("schedule-78-mig-pods", 78, || {
        let cfg = aiinfn::platform::PlatformConfig::load(&aiinfn::platform::default_config_path()).unwrap();
        let mut store = ClusterStore::new();
        for n in cfg.build_nodes().unwrap() {
            store.add_node(n, 0.0);
        }
        for i in 0..78 {
            store.create_pod(
                PodSpec::new(
                    format!("p{i}"),
                    ResourceVec::cpu_millis(2000).with("nvidia.com/mig-1g.5gb", 1),
                    Payload::Sleep { duration: 1.0 },
                ),
                0.0,
            );
        }
        let sched = Scheduler::default();
        aiinfn::util::bench::black_box(sched.schedule_pending(&mut store, 0.0));
    });
    println!("\nE2 MIG-sharing checks PASSED (7 users/A100 reproduced)");
}
