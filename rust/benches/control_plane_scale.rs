//! Control-plane scale benchmark: the 10 000-node / 100 000-pod regime the
//! sharded multi-coordinator plane targets, plus the 5 000-API-object read
//! plane and a live federated regime. Three sections feed
//! `BENCH_scale.json`:
//!
//! * **shard sweep** — the full 100k-pod drain and a fixed-total-work
//!   steady-state churn cycle, run at `shard_count ∈ {1, 2, 4, 8}` over
//!   per-shard `ClusterStore`s (contiguous zone blocks, the same partition
//!   the federation's router hands out). Each shard's wall time is
//!   reported individually; throughput is computed on the *critical path*
//!   (slowest shard), which is what a lockstep federation tick pays. The
//!   sweep must show multi-shard beating `shard_count = 1` on the same
//!   workload — that inequality is asserted, not eyeballed.
//! * **API plane** — label/field-selector lists at 5k objects and watch
//!   catch-up, indexed vs. the brute-force baselines, unchanged from the
//!   perf-refactor bench so the speedup series stays comparable.
//! * **federated regime** — a live 4-shard [`Federation`]: a burst that
//!   overflows one shard's quota and exercises the two-phase
//!   reserve/bind path, per-shard tick cost via `step_timed`, merged
//!   list/watch ops/sec, and the reservation-ledger conservation counters.
//!
//! Emits `BENCH_scale.json` (flat numerics at top level for CI's diff,
//! per-shard vectors nested) alongside the `BENCH\t…` rows, then the
//! MIG-demand regime writes `BENCH_gpu.json`. `AIINFN_BENCH_FAST=1`
//! shortens the timed `g.bench()` loops but the sweep always runs the
//! full 10k/100k regime — it is one drain + a bounded churn cycle per
//! shard count, and the regime *is* the measurement.

mod scale_reads;

use std::time::Instant;

use aiinfn::api::{ApiObject, ApiServer, ResourceKind, Selector};
use aiinfn::cluster::node::Node;
use aiinfn::cluster::pod::{Payload, PodSpec};
use aiinfn::cluster::resources::{ResourceVec, GPU, MEMORY};
use aiinfn::cluster::scheduler::Scheduler;
use aiinfn::cluster::store::ClusterStore;
use aiinfn::gpu::{GpuDevice, GpuModel};
use aiinfn::platform::{default_config_path, Federation, PlatformConfig};
use aiinfn::queue::kueue::PriorityClass;
use aiinfn::util::bench::{black_box, BenchGroup};
use aiinfn::util::json::Json;

const API_NODES: usize = 1_000;
const API_OBJECTS: usize = 5_000;
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

// The sweep runs full-size even under AIINFN_BENCH_FAST: it is one
// drain + a bounded churn cycle per shard count (not a timed loop), and
// the 10k/100k regime is the point of the measurement. The g.bench()
// timed sections still shrink via BenchConfig's fast mode.
const SWEEP_NODES: usize = 10_000;
const SWEEP_PODS: usize = SWEEP_NODES * 10;

/// Build one shard's store: the contiguous block `[lo, hi)` of the global
/// node inventory, every 4th node carrying 4 T4s (so each block has the
/// same CPU/GPU mix and the sweep compares like against like).
fn shard_store(lo: usize, hi: usize) -> ClusterStore {
    let mut s = ClusterStore::new();
    s.set_event_capacity(65_536);
    for i in lo..hi {
        let gpus = if (i - lo) % 4 == 0 {
            (0..4).map(|g| GpuDevice::whole(format!("n{i}-g{g}"), GpuModel::TeslaT4)).collect()
        } else {
            Vec::new()
        };
        s.add_node(Node::physical(format!("node-{i:05}"), 64, 256 << 30, 4 << 40, gpus), 0.0);
    }
    s
}

fn cpu_pod(name: String) -> PodSpec {
    PodSpec::new(
        name,
        ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
        Payload::Sleep { duration: 60.0 },
    )
}

fn gpu_pod(name: String) -> PodSpec {
    PodSpec::new(
        name,
        ResourceVec::cpu_millis(2000).with(MEMORY, 8 << 30).with(GPU, 1),
        Payload::Sleep { duration: 60.0 },
    )
}

/// One sweep point: drain + churn at a given shard count.
struct SweepPoint {
    shard_count: usize,
    drain_secs: Vec<f64>,
    drain_pods_per_sec: f64,
    churn_secs: Vec<f64>,
    churn_pods_per_sec: f64,
}

fn sweep_point(g: &mut BenchGroup, shard_count: usize, nodes: usize, pods: usize) -> SweepPoint {
    let per_nodes = nodes / shard_count;
    let per_pods = pods / shard_count;
    let sched = Scheduler::default();
    let mut stores: Vec<ClusterStore> =
        (0..shard_count).map(|s| shard_store(s * per_nodes, (s + 1) * per_nodes)).collect();

    // full drain: every shard schedules its 10-pods-per-node backlog; the
    // lockstep tick pays the slowest shard, so the critical path is max.
    let mut drain_secs = Vec::with_capacity(shard_count);
    for (s, store) in stores.iter_mut().enumerate() {
        for j in 0..per_pods {
            let name = format!("pod-{s}-{j:05}");
            let spec = if j % 10 == 0 { gpu_pod(name) } else { cpu_pod(name) };
            store.create_pod(spec, 0.0);
        }
        let t = Instant::now();
        let (placed, failed) = sched.schedule_pending(store, 1.0);
        drain_secs.push(t.elapsed().as_secs_f64());
        assert!(
            failed.is_empty(),
            "shard {s}/{shard_count}: the drain must fit its block: {failed:?}"
        );
        assert_eq!(placed.len(), per_pods);
        store.check_free_index();
    }
    let drain_critical = drain_secs.iter().cloned().fold(0.0_f64, f64::max);
    let drain_pods_per_sec = pods as f64 / drain_critical;
    g.record_value(&format!("drain_s{shard_count}_pods_per_sec"), drain_pods_per_sec, "pods/s");

    // steady-state churn: a fixed federation-wide batch of new pods per
    // tick, split evenly across shards against the warm (drained) stores,
    // then removed so the cycle repeats identically.
    let total_churn = 800;
    let per_churn = total_churn / shard_count;
    let iters = 10;
    let mut churn_secs = vec![0.0_f64; shard_count];
    let mut serial = 0usize;
    for _ in 0..iters {
        for (s, store) in stores.iter_mut().enumerate() {
            let t = Instant::now();
            let names: Vec<String> = (0..per_churn)
                .map(|_| {
                    serial += 1;
                    let name = format!("churn-{s}-{serial:07}");
                    store.create_pod(cpu_pod(name.clone()), 2.0);
                    name
                })
                .collect();
            let (placed, _failed) = sched.schedule_pending(store, 2.0);
            black_box(placed.len());
            for n in &names {
                store.delete_pod(n, 2.0, "bench churn").unwrap();
            }
            churn_secs[s] += t.elapsed().as_secs_f64();
        }
    }
    for c in &mut churn_secs {
        *c /= iters as f64;
    }
    let churn_critical = churn_secs.iter().cloned().fold(0.0_f64, f64::max);
    let churn_pods_per_sec = total_churn as f64 / churn_critical;
    g.record_value(&format!("churn_s{shard_count}_pods_per_sec"), churn_pods_per_sec, "pods/s");

    SweepPoint { shard_count, drain_secs, drain_pods_per_sec, churn_secs, churn_pods_per_sec }
}

fn main() {
    let mut g = BenchGroup::new("control_plane_scale");

    // ------------------------------------------- sharded scheduler sweep
    let (nodes, pods) = (SWEEP_NODES, SWEEP_PODS);
    let sweep: Vec<SweepPoint> =
        SHARD_COUNTS.iter().map(|&s| sweep_point(&mut g, s, nodes, pods)).collect();
    let single = &sweep[0];
    let best_drain =
        sweep[1..].iter().map(|p| p.drain_pods_per_sec).fold(0.0_f64, f64::max);
    let best_churn =
        sweep[1..].iter().map(|p| p.churn_pods_per_sec).fold(0.0_f64, f64::max);
    assert!(
        best_drain > single.drain_pods_per_sec,
        "multi-shard drain throughput must beat shard_count=1 \
         ({best_drain:.0} vs {:.0} pods/s)",
        single.drain_pods_per_sec
    );
    assert!(
        best_churn > single.churn_pods_per_sec,
        "multi-shard churn throughput must beat shard_count=1 \
         ({best_churn:.0} vs {:.0} pods/s)",
        single.churn_pods_per_sec
    );

    // ------------------------------------------------- API plane at scale
    // 1 000-server inventory (CPU-only for bootstrap speed), 5 000 batch
    // jobs with a 1% hot-labeled subset.
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..API_NODES)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("srv-{i:04}");
            s.cpu_cores = 64;
            s.memory_gb = 256;
            s.nvme_tb = 4;
            s.gpus = Vec::new();
            s
        })
        .collect();
    cfg.federation_enabled = false;
    let mut api = ApiServer::bootstrap(cfg).unwrap();
    let token = api.login("user001").unwrap();

    // hot-label list (1% selectivity) + watch catch-up, indexed vs the
    // in-run baselines — shared harness with the api_verbs bench
    scale_reads::populate(&mut api, &token, "user001", API_OBJECTS, API_OBJECTS / 100);
    let reads = scale_reads::bench_reads(&mut g, &api, &token);

    // list: field selector over 1k nodes — typed evaluator vs to_json
    let virt = Selector::fields("spec.virtual=false").unwrap();
    let list_field = {
        let r = g.bench("list_1k_nodes_field_typed", || {
            black_box(api.list(&token, ResourceKind::Node, &virt).unwrap());
        });
        r.per_sec()
    };
    let list_field_baseline = {
        let r = g.bench("list_1k_nodes_field_bruteforce", || {
            let all = api.list(&token, ResourceKind::Node, &Selector::all()).unwrap();
            let matched: Vec<ApiObject> =
                all.into_iter().filter(|o| virt.matches(&o.to_json())).collect();
            black_box(matched);
        });
        r.per_sec()
    };

    // reconcile ticks at scale: first ticks admit + place the 5k jobs,
    // then the steady state measures per-tick control-plane overhead
    for _ in 0..5 {
        api.tick();
    }
    let tick = {
        let r = g.bench("api_tick_steady_5k", || {
            api.tick();
        });
        r.per_sec()
    };

    // ring-log occupancy after everything above: bounded by the window
    let window = api.platform().config.compaction_window;
    let event_ring = api.platform().cluster().events().len();
    assert!(event_ring <= window, "event ring exceeded the compaction window");
    let watch_log_len = api.watch_log_len();

    // --------------------------------------------- live federated regime
    // A 4-shard federation over 64 identical servers. One user's burst
    // overflows its home shard's quota, so a slice of the submissions
    // must travel the two-phase reserve/bind path; then the steady state
    // measures per-shard tick cost and the merged read plane.
    let fed_shards = 4usize;
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..64)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("fsrv-{i:02}");
            s.cpu_cores = 64;
            s.memory_gb = 256;
            s.nvme_tb = 4;
            s.gpus = Vec::new();
            s
        })
        .collect();
    cfg.federation_enabled = false;
    cfg.shard_count = fed_shards;
    let mut fed = Federation::bootstrap(cfg).unwrap();
    let heavy = (0..78)
        .map(|u| format!("user{u:03}"))
        .find(|u| fed.home_shard(u) == 1)
        .expect("some user routes to shard 1");
    // one shard's quota is 16 servers × 62 allocatable cores = 992; every
    // 16-core job past the 62nd must go cross-shard
    let burst = 120;
    for _ in 0..burst {
        fed.submit_batch(
            &heavy,
            "project05",
            ResourceVec::cpu_millis(16_000).with(MEMORY, 32 << 30),
            7200.0,
            PriorityClass::Batch,
            false,
        )
        .unwrap();
    }
    // warm up: reserve/bind settles, pods place, reconcilers reach steady
    // state with the cluster loaded
    for _ in 0..6 {
        fed.step(15.0);
    }
    assert!(
        fed.metrics().cross_shard_submissions > 0,
        "the burst must overflow its home shard into the two-phase path"
    );
    assert!(fed.ledger().balanced(), "reservation ledger must stay conserved");

    let iters = 20;
    let mut fed_tick_secs = vec![0.0_f64; fed_shards];
    let t = Instant::now();
    let cursor_mid = fed.cursor_now();
    for _ in 0..iters {
        for (s, secs) in fed.step_timed(15.0).into_iter().enumerate() {
            fed_tick_secs[s] += secs;
        }
    }
    let fed_ticks_per_sec = iters as f64 / t.elapsed().as_secs_f64();
    for s in &mut fed_tick_secs {
        *s /= iters as f64;
    }
    g.record_value("fed_ticks_per_sec", fed_ticks_per_sec, "ticks/s");

    let tokens = fed.login(&heavy).unwrap();
    let fed_list = {
        let r = g.bench("fed_list_merged_pods", || {
            black_box(fed.list_merged(&tokens, ResourceKind::Pod, &Selector::all()).unwrap());
        });
        r.per_sec()
    };
    let fed_watch = {
        let r = g.bench("fed_watch_merged_catchup", || {
            black_box(fed.watch_merged(&tokens, ResourceKind::Pod, &cursor_mid).unwrap());
        });
        r.per_sec()
    };
    let ledger = fed.ledger().stats();
    let fm = fed.metrics().clone();

    let sweep_json = Json::Arr(
        sweep
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("shard_count", Json::num(p.shard_count as f64)),
                    (
                        "drain_secs_per_shard",
                        Json::Arr(p.drain_secs.iter().map(|&s| Json::num(s)).collect()),
                    ),
                    ("drain_pods_per_sec", Json::num(p.drain_pods_per_sec)),
                    (
                        "drain_speedup",
                        Json::num(p.drain_pods_per_sec / single.drain_pods_per_sec),
                    ),
                    (
                        "churn_secs_per_shard",
                        Json::Arr(p.churn_secs.iter().map(|&s| Json::num(s)).collect()),
                    ),
                    ("churn_pods_per_sec", Json::num(p.churn_pods_per_sec)),
                    (
                        "churn_speedup",
                        Json::num(p.churn_pods_per_sec / single.churn_pods_per_sec),
                    ),
                ])
            })
            .collect(),
    );

    let mut pairs = vec![
        ("nodes", Json::num(nodes as f64)),
        ("pods_drained", Json::num(pods as f64)),
        ("api_objects", Json::num(reads.objects as f64)),
        // shard_count=1 point keeps the pre-sharding key names so the
        // series stays diffable across the refactor
        ("drain_pods_per_sec", Json::num(single.drain_pods_per_sec)),
        ("tick_schedule_pods_per_sec", Json::num(single.churn_pods_per_sec)),
    ];
    let mut flat_keys: Vec<(String, f64)> = Vec::new();
    for p in &sweep {
        flat_keys.push((format!("drain_s{}_pods_per_sec", p.shard_count), p.drain_pods_per_sec));
        flat_keys.push((format!("churn_s{}_pods_per_sec", p.shard_count), p.churn_pods_per_sec));
    }
    flat_keys.push(("drain_best_speedup".into(), best_drain / single.drain_pods_per_sec));
    flat_keys.push(("churn_best_speedup".into(), best_churn / single.churn_pods_per_sec));
    for (k, v) in &flat_keys {
        pairs.push((k.as_str(), Json::num(*v)));
    }
    pairs.extend(vec![
        ("shard_sweep", sweep_json),
        ("list_label_ops_per_sec", Json::num(reads.list_indexed)),
        ("list_label_baseline_ops_per_sec", Json::num(reads.list_baseline)),
        ("list_label_speedup", Json::num(reads.list_speedup())),
        ("list_field_ops_per_sec", Json::num(list_field)),
        ("list_field_baseline_ops_per_sec", Json::num(list_field_baseline)),
        (
            "list_field_speedup",
            Json::num(list_field / list_field_baseline.max(f64::MIN_POSITIVE)),
        ),
        ("watch_ops_per_sec", Json::num(reads.watch_indexed)),
        ("watch_baseline_ops_per_sec", Json::num(reads.watch_baseline)),
        ("watch_speedup", Json::num(reads.watch_speedup())),
        ("api_ticks_per_sec", Json::num(tick)),
        ("compaction_window", Json::num(window as f64)),
        ("event_ring_len", Json::num(event_ring as f64)),
        ("watch_log_len", Json::num(watch_log_len as f64)),
        ("fed_shards", Json::num(fed_shards as f64)),
        ("fed_ticks_per_sec", Json::num(fed_ticks_per_sec)),
        (
            "fed_tick_secs_per_shard",
            Json::Arr(fed_tick_secs.iter().map(|&s| Json::num(s)).collect()),
        ),
        ("fed_list_merged_ops_per_sec", Json::num(fed_list)),
        ("fed_watch_merged_ops_per_sec", Json::num(fed_watch)),
        ("fed_local_submissions", Json::num(fm.local_submissions as f64)),
        ("fed_cross_shard_submissions", Json::num(fm.cross_shard_submissions as f64)),
        ("fed_cross_shard_binds", Json::num(fm.cross_shard_binds as f64)),
        ("fed_fallback_binds", Json::num(fm.fallback_binds as f64)),
        ("fed_ledger_created", Json::num(ledger.created as f64)),
        ("fed_ledger_bound", Json::num(ledger.bound as f64)),
        ("fed_ledger_released", Json::num(ledger.released as f64)),
        ("fed_ledger_expired", Json::num(ledger.expired as f64)),
    ]);
    let out = Json::obj(pairs);
    std::fs::write("BENCH_scale.json", out.to_pretty()).expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");

    // ---------------------------------------------- mixed MIG-demand regime
    // Demand-driven repartitioning at fleet scale: 8 dual-A100 servers boot
    // **cold** (every device whole), then 8 whole-GPU users and 56
    // single-slice users arrive at once. The partition reconciler must
    // leave the whole-GPU devices alone and flip the idle half of the
    // fleet to 7×1g.5gb; we measure the ticks + wall time to the
    // all-64-users-running fixed point and the steady-state tick cost with
    // the gpu controller active.
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..8)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("mig-{i:02}");
            s.cpu_cores = 128;
            s.memory_gb = 512;
            s.nvme_tb = 4;
            s.gpus = vec![GpuModel::A100_40GB; 2];
            s
        })
        .collect();
    cfg.a100_layout.clear(); // cold: no MIG layout configured
    cfg.federation_enabled = false;
    cfg.repartition_cooldown = 30.0;
    let mut api = ApiServer::bootstrap(cfg).unwrap();
    {
        let p = api.platform_mut();
        for i in 0..8 {
            p.submit_batch(
                &format!("user{:03}", i),
                "project01",
                ResourceVec::cpu_millis(2000).with(MEMORY, 8 << 30).with(GPU, 1),
                1e6,
                PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
        for i in 0..56 {
            p.submit_batch(
                &format!("user{:03}", (8 + i) % 78),
                "project01",
                ResourceVec::cpu_millis(1000)
                    .with(MEMORY, 4 << 30)
                    .with("nvidia.com/mig-1g.5gb", 1),
                1e6,
                PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
    }
    let running = |api: &ApiServer| {
        let st = api.platform().cluster();
        st.pods()
            .filter(|p| p.status.phase == aiinfn::cluster::pod::PodPhase::Running)
            .count()
    };
    let t = Instant::now();
    let mut converge_ticks = 0usize;
    while converge_ticks < 500 && running(&api) < 64 {
        api.run_for(10.0, 10.0); // one 10 s control tick
        converge_ticks += 1;
    }
    let converge_secs = t.elapsed().as_secs_f64();
    let users = running(&api);
    assert_eq!(users, 64, "MIG-demand regime must converge to 64 running users");
    let repartitions = api.platform().metrics().repartitions;
    assert_eq!(repartitions, 8, "exactly the idle half of the fleet flips");
    g.record_value("gpu_converge_ticks", converge_ticks as f64, "ticks");
    g.record_value("gpu_converge_secs", converge_secs, "s");

    // steady state: demand satisfied, gpu controller still scanning
    let gpu_tick = {
        let r = g.bench("gpu_regime_tick_steady", || {
            api.tick();
        });
        r.per_sec()
    };

    let out = Json::obj(vec![
        ("a100_devices", Json::num(16.0)),
        ("whole_gpu_users", Json::num(8.0)),
        ("mig_slice_users", Json::num(56.0)),
        ("users_running", Json::num(users as f64)),
        ("repartitions", Json::num(repartitions as f64)),
        ("converge_ticks", Json::num(converge_ticks as f64)),
        ("converge_secs", Json::num(converge_secs)),
        ("steady_ticks_per_sec", Json::num(gpu_tick)),
    ]);
    std::fs::write("BENCH_gpu.json", out.to_pretty()).expect("write BENCH_gpu.json");
    println!("wrote BENCH_gpu.json");
}
