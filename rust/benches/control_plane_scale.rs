//! Control-plane scale benchmark: the 1 000-node / 10 000-pod / 5 000-API-
//! object regime the National-Research-Platform-style multi-tenant
//! deployments live in. Exercises the three pruned hot paths of the
//! perf refactor and measures each against its pre-change baseline **in
//! the same run**:
//!
//! * **schedule** — a full 10k-pod drain through the free-capacity-indexed
//!   scheduler over 1k nodes, plus the steady-state 100-pods-per-tick
//!   churn cycle;
//! * **list** — label-selector and field-selector lists at 5k objects via
//!   the inverted-label/typed-evaluator path vs. the brute-force
//!   serialize-every-object filter (the former code path, still available
//!   as `Selector::matches` on JSON);
//! * **watch** — catch-up reads from the per-kind sharded log vs. the
//!   scan-every-kind baseline.
//!
//! Emits `BENCH_scale.json` (ops/sec + speedups + ring-log occupancy as
//! bounded-memory evidence) alongside the `BENCH\t…` rows. CI uploads the
//! file and diffs it against the committed previous run.

mod scale_reads;

use std::time::Instant;

use aiinfn::api::{ApiObject, ApiServer, ResourceKind, Selector};
use aiinfn::cluster::node::Node;
use aiinfn::cluster::pod::{Payload, PodSpec};
use aiinfn::cluster::resources::{ResourceVec, GPU, MEMORY};
use aiinfn::cluster::scheduler::Scheduler;
use aiinfn::cluster::store::ClusterStore;
use aiinfn::gpu::{GpuDevice, GpuModel};
use aiinfn::platform::{default_config_path, PlatformConfig};
use aiinfn::util::bench::{black_box, BenchGroup};
use aiinfn::util::json::Json;

const NODES: usize = 1_000;
const PODS: usize = 10_000;
const API_OBJECTS: usize = 5_000;

/// 1 000 nodes: three quarters CPU-only, one quarter with 4 T4s each.
fn big_store() -> ClusterStore {
    let mut s = ClusterStore::new();
    s.set_event_capacity(65_536);
    for i in 0..NODES {
        let gpus = if i % 4 == 0 {
            (0..4).map(|g| GpuDevice::whole(format!("n{i}-g{g}"), GpuModel::TeslaT4)).collect()
        } else {
            Vec::new()
        };
        s.add_node(Node::physical(format!("node-{i:04}"), 64, 256 << 30, 4 << 40, gpus), 0.0);
    }
    s
}

fn cpu_pod(name: String) -> PodSpec {
    PodSpec::new(
        name,
        ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
        Payload::Sleep { duration: 60.0 },
    )
}

fn gpu_pod(name: String) -> PodSpec {
    PodSpec::new(
        name,
        ResourceVec::cpu_millis(2000).with(MEMORY, 8 << 30).with(GPU, 1),
        Payload::Sleep { duration: 60.0 },
    )
}

fn main() {
    let mut g = BenchGroup::new("control_plane_scale");

    // ------------------------------------------------ scheduler at scale
    let mut store = big_store();
    let sched = Scheduler::default();
    for i in 0..PODS {
        let spec = if i % 10 == 0 {
            gpu_pod(format!("pod-{i:05}"))
        } else {
            cpu_pod(format!("pod-{i:05}"))
        };
        store.create_pod(spec, 0.0);
    }
    let t = Instant::now();
    let (placed, failed) = sched.schedule_pending(&mut store, 1.0);
    let drain_secs = t.elapsed().as_secs_f64();
    assert!(failed.is_empty(), "the 10k drain must fit 1k nodes: {failed:?}");
    assert_eq!(placed.len(), PODS);
    let drain_pods_per_sec = PODS as f64 / drain_secs;
    g.record_value("drain_10k_pods_per_sec", drain_pods_per_sec, "pods/s");
    store.check_free_index();

    // steady-state churn: 100 new pods per "tick" against a warm cluster,
    // then removed so the cycle is repeatable
    let mut serial = 0usize;
    let tick_sched = {
        let r = g.bench_elements("tick_schedule_100", 100, || {
            let names: Vec<String> = (0..100)
                .map(|_| {
                    serial += 1;
                    let name = format!("churn-{serial:07}");
                    store.create_pod(cpu_pod(name.clone()), 2.0);
                    name
                })
                .collect();
            let (placed, _failed) = sched.schedule_pending(&mut store, 2.0);
            black_box(placed.len());
            for n in &names {
                store.delete_pod(n, 2.0, "bench churn").unwrap();
            }
        });
        r.per_sec()
    };

    // ------------------------------------------------- API plane at scale
    // 1 000-server inventory (CPU-only for bootstrap speed), 5 000 batch
    // jobs with a 1% hot-labeled subset.
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..NODES)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("srv-{i:04}");
            s.cpu_cores = 64;
            s.memory_gb = 256;
            s.nvme_tb = 4;
            s.gpus = Vec::new();
            s
        })
        .collect();
    cfg.federation_enabled = false;
    let mut api = ApiServer::bootstrap(cfg).unwrap();
    let token = api.login("user001").unwrap();

    // hot-label list (1% selectivity) + watch catch-up, indexed vs the
    // in-run baselines — shared harness with the api_verbs bench
    scale_reads::populate(&mut api, &token, "user001", API_OBJECTS, API_OBJECTS / 100);
    let reads = scale_reads::bench_reads(&mut g, &api, &token);

    // list: field selector over 1k nodes — typed evaluator vs to_json
    let virt = Selector::fields("spec.virtual=false").unwrap();
    let list_field = {
        let r = g.bench("list_1k_nodes_field_typed", || {
            black_box(api.list(&token, ResourceKind::Node, &virt).unwrap());
        });
        r.per_sec()
    };
    let list_field_baseline = {
        let r = g.bench("list_1k_nodes_field_bruteforce", || {
            let all = api.list(&token, ResourceKind::Node, &Selector::all()).unwrap();
            let matched: Vec<ApiObject> =
                all.into_iter().filter(|o| virt.matches(&o.to_json())).collect();
            black_box(matched);
        });
        r.per_sec()
    };

    // reconcile ticks at scale: first ticks admit + place the 5k jobs,
    // then the steady state measures per-tick control-plane overhead
    for _ in 0..5 {
        api.tick();
    }
    let tick = {
        let r = g.bench("api_tick_steady_5k", || {
            api.tick();
        });
        r.per_sec()
    };

    // ring-log occupancy after everything above: bounded by the window
    let window = api.platform().config.compaction_window;
    let event_ring = api.platform().cluster().events().len();
    assert!(event_ring <= window, "event ring exceeded the compaction window");

    let out = Json::obj(vec![
        ("nodes", Json::num(NODES as f64)),
        ("pods_drained", Json::num(PODS as f64)),
        ("api_objects", Json::num(reads.objects as f64)),
        ("drain_pods_per_sec", Json::num(drain_pods_per_sec)),
        ("tick_schedule_pods_per_sec", Json::num(tick_sched)),
        ("list_label_ops_per_sec", Json::num(reads.list_indexed)),
        ("list_label_baseline_ops_per_sec", Json::num(reads.list_baseline)),
        ("list_label_speedup", Json::num(reads.list_speedup())),
        ("list_field_ops_per_sec", Json::num(list_field)),
        ("list_field_baseline_ops_per_sec", Json::num(list_field_baseline)),
        (
            "list_field_speedup",
            Json::num(list_field / list_field_baseline.max(f64::MIN_POSITIVE)),
        ),
        ("watch_ops_per_sec", Json::num(reads.watch_indexed)),
        ("watch_baseline_ops_per_sec", Json::num(reads.watch_baseline)),
        ("watch_speedup", Json::num(reads.watch_speedup())),
        ("api_ticks_per_sec", Json::num(tick)),
        ("compaction_window", Json::num(window as f64)),
        ("event_ring_len", Json::num(event_ring as f64)),
        ("watch_log_len", Json::num(api.watch_log_len() as f64)),
    ]);
    std::fs::write("BENCH_scale.json", out.to_pretty()).expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");

    // ---------------------------------------------- mixed MIG-demand regime
    // Demand-driven repartitioning at fleet scale: 8 dual-A100 servers boot
    // **cold** (every device whole), then 8 whole-GPU users and 56
    // single-slice users arrive at once. The partition reconciler must
    // leave the whole-GPU devices alone and flip the idle half of the
    // fleet to 7×1g.5gb; we measure the ticks + wall time to the
    // all-64-users-running fixed point and the steady-state tick cost with
    // the gpu controller active.
    let mut cfg = PlatformConfig::load(&default_config_path()).unwrap();
    let template = cfg.servers[0].clone();
    cfg.servers = (0..8)
        .map(|i| {
            let mut s = template.clone();
            s.name = format!("mig-{i:02}");
            s.cpu_cores = 128;
            s.memory_gb = 512;
            s.nvme_tb = 4;
            s.gpus = vec![GpuModel::A100_40GB; 2];
            s
        })
        .collect();
    cfg.a100_layout.clear(); // cold: no MIG layout configured
    cfg.federation_enabled = false;
    cfg.repartition_cooldown = 30.0;
    let mut api = ApiServer::bootstrap(cfg).unwrap();
    {
        let p = api.platform_mut();
        for i in 0..8 {
            p.submit_batch(
                &format!("user{:03}", i),
                "project01",
                ResourceVec::cpu_millis(2000).with(MEMORY, 8 << 30).with(GPU, 1),
                1e6,
                aiinfn::queue::kueue::PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
        for i in 0..56 {
            p.submit_batch(
                &format!("user{:03}", (8 + i) % 78),
                "project01",
                ResourceVec::cpu_millis(1000)
                    .with(MEMORY, 4 << 30)
                    .with("nvidia.com/mig-1g.5gb", 1),
                1e6,
                aiinfn::queue::kueue::PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
    }
    let running = |api: &ApiServer| {
        let st = api.platform().cluster();
        st.pods()
            .filter(|p| p.status.phase == aiinfn::cluster::pod::PodPhase::Running)
            .count()
    };
    let t = Instant::now();
    let mut converge_ticks = 0usize;
    while converge_ticks < 500 && running(&api) < 64 {
        api.run_for(10.0, 10.0); // one 10 s control tick
        converge_ticks += 1;
    }
    let converge_secs = t.elapsed().as_secs_f64();
    let users = running(&api);
    assert_eq!(users, 64, "MIG-demand regime must converge to 64 running users");
    let repartitions = api.platform().metrics().repartitions;
    assert_eq!(repartitions, 8, "exactly the idle half of the fleet flips");
    g.record_value("gpu_converge_ticks", converge_ticks as f64, "ticks");
    g.record_value("gpu_converge_secs", converge_secs, "s");

    // steady state: demand satisfied, gpu controller still scanning
    let gpu_tick = {
        let r = g.bench("gpu_regime_tick_steady", || {
            api.tick();
        });
        r.per_sec()
    };

    let out = Json::obj(vec![
        ("a100_devices", Json::num(16.0)),
        ("whole_gpu_users", Json::num(8.0)),
        ("mig_slice_users", Json::num(56.0)),
        ("users_running", Json::num(users as f64)),
        ("repartitions", Json::num(repartitions as f64)),
        ("converge_ticks", Json::num(converge_ticks as f64)),
        ("converge_secs", Json::num(converge_secs)),
        ("steady_ticks_per_sec", Json::num(gpu_tick)),
    ]);
    std::fs::write("BENCH_gpu.json", out.to_pretty()).expect("write BENCH_gpu.json");
    println!("wrote BENCH_gpu.json");
}
