//! E1 — §2 commissioning inventory: regenerate the paper's hardware table
//! from the config and verify the platform advertises exactly that
//! capacity; also measures the cold-boot time of the full platform.

use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::util::bench::BenchGroup;
use aiinfn::util::fmt_bytes;

fn main() {
    let mut g = BenchGroup::new("E1-inventory");
    let cfg = PlatformConfig::load(&default_config_path()).expect("config");

    // The paper's table, regenerated:
    println!("\n| server | year | cores | memory | nvme | NVIDIA | FPGA |");
    println!("|---|---|---|---|---|---|---|");
    for s in &cfg.servers {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            s.name,
            s.year,
            s.cpu_cores,
            fmt_bytes((s.memory_gb as u64) << 30),
            fmt_bytes((s.nvme_tb as u64) << 40),
            s.gpus.iter().filter(|x| !x.is_fpga()).count(),
            s.gpus.iter().filter(|x| x.is_fpga()).count(),
        );
    }
    let (cores, mem, nvme, gpus, fpgas) = cfg.totals();
    println!("| TOTAL | 2020-24 | {cores} | {} | {} | {gpus} | {fpgas} |", fmt_bytes(mem as u64), fmt_bytes(nvme as u64));

    // functional checks (paper §2 numbers)
    assert_eq!(cfg.servers.len(), 4);
    assert_eq!(cores, 448);
    assert_eq!(gpus, 20);
    assert_eq!(fpgas, 10);
    let nodes = cfg.build_nodes().unwrap();
    let mig: i64 = nodes.iter().map(|n| n.allocatable.get("nvidia.com/mig-1g.5gb")).sum();
    assert_eq!(mig, 35, "5 A100 × 7 MIG slices");
    g.record_value("registered-users", 78.0, "users");
    g.record_value("projects", 20.0, "projects");
    g.record_value("mig-slices", mig as f64, "slices");

    // platform cold-boot latency
    let cfg2 = cfg.clone();
    g.bench("platform-bootstrap", || {
        let p = Platform::bootstrap(cfg2.clone()).unwrap();
        aiinfn::util::bench::black_box(p.node_count());
    });
    println!("\nE1 inventory checks PASSED");
}
