//! Shared fixtures for the integration test binaries
//! (`api_watch`, `offload`, `scheduling`, `chaos`).

use aiinfn::api::ApiServer;
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::{default_config_path, Platform, PlatformConfig};
use aiinfn::queue::kueue::PriorityClass;

/// The paper's bundled platform config.
#[allow(dead_code)]
pub fn config() -> PlatformConfig {
    PlatformConfig::load(&default_config_path()).unwrap()
}

/// A bootstrapped platform (4 physical servers + 4 federation sites).
#[allow(dead_code)]
pub fn platform() -> Platform {
    Platform::bootstrap(config()).unwrap()
}

/// A bootstrapped platform wrapped in the control-plane API server.
#[allow(dead_code)]
pub fn api() -> ApiServer {
    ApiServer::bootstrap(config()).unwrap()
}

/// Submit `n` CPU batch jobs (`cpu_millis` each, 32 GiB) from rotating
/// users; returns the workload names.
#[allow(dead_code)]
pub fn submit_cpu_batch(
    p: &mut Platform,
    n: usize,
    cpu_millis: i64,
    duration: f64,
    offloadable: bool,
) -> Vec<String> {
    (0..n)
        .map(|i| {
            p.submit_batch(
                &format!("user{:03}", i % 78),
                "project05",
                ResourceVec::cpu_millis(cpu_millis).with(MEMORY, 32 << 30),
                duration,
                PriorityClass::Batch,
                offloadable,
            )
            .unwrap()
        })
        .collect()
}

/// Base seed for the randomized suites. CI runs the whole test suite under
/// two fixed `AIINFN_TEST_SEED` values (and two `--test-threads` settings)
/// to catch seed-dependent flakiness and cross-test nondeterminism.
#[allow(dead_code)]
pub fn test_seed() -> u64 {
    std::env::var("AIINFN_TEST_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(7)
}
