//! Sharded-control-plane suite: `shard_count = 1` golden-trace parity
//! against the single-coordinator plane, the 8-seed cross-shard two-phase
//! invariant sweep, rebalancing, and the merged-watch contract.

mod common;

use aiinfn::api::{ApiError, FederatedCursor, ResourceKind, Selector};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::{Federation, FederatedJobPhase, Platform, PlatformConfig};
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::sim::chaos::ChaosPlan;
use aiinfn::sim::clock::hours;

/// A small homogeneous inventory (no GPUs, no InterLink federation) that
/// partitions cleanly across shard counts.
fn small_config(shards: usize) -> PlatformConfig {
    let servers: Vec<String> = (0..4)
        .map(|i| format!(r#"{{"name":"node-{i:02}","cpu_cores":16,"memory_gb":64,"nvme_tb":1}}"#))
        .collect();
    let raw = format!(
        r#"{{"servers":[{}],"sharding":{{"shard_count":{shards}}}}}"#,
        servers.join(",")
    );
    PlatformConfig::parse(&raw).expect("test config parses")
}

/// Every platform-side transition as one text blob — the same assembly
/// the chaos suite's golden-trace test uses (chaos log, cluster events,
/// Kueue workload transitions, site-health transitions).
fn platform_trace(p: &Platform) -> String {
    let mut out = String::new();
    if let Some(c) = p.chaos() {
        out.push_str(&c.trace());
    }
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    for t in p.health().transitions_since(0) {
        out.push_str(&format!(
            "{:10.3} HEALTH {} {} {}\n",
            t.at,
            t.site,
            t.status.as_str(),
            t.reason
        ));
    }
    out
}

fn chaos_plan(seed: u64) -> ChaosPlan {
    ChaosPlan {
        seed,
        horizon: 1200.0,
        site_outages_per_hour: 2.0,
        wire_faults_per_hour: 4.0,
        remote_job_failures_per_hour: 2.0,
        node_flaps_per_hour: 1.0,
        ..Default::default()
    }
}

// --------------------------------------------------------- parity (1 shard)

/// The pre-refactor single-coordinator run of one chaos campaign.
fn single_coordinator_trace(seed: u64) -> String {
    let mut p = Platform::bootstrap(common::config()).unwrap();
    p.install_chaos(&chaos_plan(seed));
    let _wls = common::submit_cpu_batch(&mut p, 20, 16_000, 400.0, true);
    p.run_for(3600.0, 15.0);
    platform_trace(&p)
}

/// The same campaign through a 1-shard federation: same config, same
/// chaos plan, same submissions in the same order, same tick cadence.
fn one_shard_federation_trace(seed: u64) -> String {
    let mut cfg = common::config();
    cfg.shard_count = 1;
    let mut fed = Federation::bootstrap(cfg).unwrap();
    fed.install_chaos(&chaos_plan(seed));
    for i in 0..20usize {
        fed.submit_batch(
            &format!("user{:03}", i % 78),
            "project05",
            ResourceVec::cpu_millis(16_000).with(MEMORY, 32 << 30),
            400.0,
            PriorityClass::Batch,
            true,
        )
        .unwrap();
    }
    fed.run_for(3600.0, 15.0);
    platform_trace(fed.platform(0))
}

/// The refactor's backstop: with one shard the federation must be a
/// pass-through, byte-identical to the pre-sharding plane per seed.
#[test]
fn one_shard_federation_matches_single_coordinator_traces() {
    let base = common::test_seed();
    for seed in [base, base.wrapping_add(1), base.wrapping_mul(31).wrapping_add(5)] {
        let single = single_coordinator_trace(seed);
        let federated = one_shard_federation_trace(seed);
        assert!(!single.is_empty());
        assert_eq!(
            single, federated,
            "seed {seed}: shard_count=1 must converge byte-identical to the \
             single-coordinator golden trace"
        );
    }
}

// ------------------------------------------------- cross-shard sweep (2φ)

/// 8-seed sweep of the two-phase cross-shard protocol under chaos: no
/// workload lost, zero double-binds, zero leaked reservations, per-shard
/// quota drained, submission accounting exact.
#[test]
fn cross_shard_two_phase_sweep_preserves_invariants() {
    let base = common::test_seed();
    for i in 0..8u64 {
        let seed = base.wrapping_mul(100).wrapping_add(i);
        let mut cfg = common::config();
        cfg.shard_count = 2;
        let mut fed = Federation::bootstrap(cfg).unwrap();
        fed.install_chaos(&ChaosPlan {
            seed,
            horizon: 1800.0,
            site_outages_per_hour: 1.0,
            outage_duration: (120.0, 400.0),
            wire_faults_per_hour: 3.0,
            remote_job_failures_per_hour: 2.0,
            node_flaps_per_hour: 0.5,
            node_down_duration: (60.0, 240.0),
            ..Default::default()
        });

        // one heavy user homed on shard 1 (physical servers only — the
        // InterLink sites stay a shard-0 concern, so shard 1 has the
        // smaller quota): the burst (40 × 16 cores ≫ its quota) must
        // overflow through the reserve/bind path
        let heavy = (0..100)
            .map(|u| format!("user{u:03}"))
            .find(|u| fed.home_shard(u) == 1)
            .unwrap();
        let n = 40usize;
        let jobs: Vec<String> = (0..n)
            .map(|j| {
                fed.submit_batch(
                    &heavy,
                    "project01",
                    ResourceVec::cpu_millis(16_000).with(MEMORY, 16 << 30),
                    300.0,
                    PriorityClass::Batch,
                    j % 2 == 0,
                )
                .unwrap()
            })
            .collect();
        let m = fed.metrics().clone();
        assert!(
            m.cross_shard_submissions > 0,
            "seed {seed}: the burst must overflow the home shard \
             (local={}, cross={})",
            m.local_submissions,
            m.cross_shard_submissions
        );

        fed.run_for(hours(4.0), 30.0);

        // (a) no workload lost: every federated job reaches Finished
        for j in &jobs {
            assert_eq!(
                fed.workload_state(j),
                Some(WorkloadState::Finished),
                "seed {seed}: job {j} stuck in {:?}",
                fed.job_phase(j)
            );
        }
        // (b) the ledger's conservation law: zero double-binds (bind
        // consumes exactly once by construction; the law catches any
        // claim counted twice) and zero leaked reservations
        let stats = fed.ledger().stats();
        assert!(fed.ledger().balanced(), "seed {seed}: {stats:?}");
        assert_eq!(
            fed.ledger().active_len(),
            0,
            "seed {seed}: reservations must all be consumed or released: {stats:?}"
        );
        assert_eq!(
            stats.created,
            stats.bound + stats.released + stats.expired,
            "seed {seed}: {stats:?}"
        );
        // (c) every submission accounted for exactly once
        let m = fed.metrics();
        assert_eq!(
            m.local_submissions + m.cross_shard_submissions,
            n as u64,
            "seed {seed}: {m:?}"
        );
        assert_eq!(
            m.cross_shard_submissions,
            m.cross_shard_binds + m.fallback_binds,
            "seed {seed}: every cross-shard submission binds somewhere: {m:?}"
        );
        // (d) per-shard quota fully drained
        for s in 0..fed.shard_count() {
            let (used, _) = fed.platform(s).quota_utilization();
            assert!(used.is_empty(), "seed {seed}: shard {s} leaked quota {used}");
        }
        // (e) free-capacity indexes exact on every shard
        assert!(fed.check_free_indexes() > 0);
    }
}

/// The reserve → bind handoff is observable: an overflowing submission
/// passes through `Reserved` (claim held, not yet bound) and binds on the
/// next federation step — never twice.
#[test]
fn reserve_then_bind_lifecycle_is_observable() {
    let mut fed = Federation::bootstrap(small_config(2)).unwrap();
    // find a user homed on shard 0, then fill shard 0's quota
    let user = (0..100)
        .map(|i| format!("user{i:03}"))
        .find(|u| fed.home_shard(u) == 0)
        .unwrap();
    // each shard: 2 × 16 cores minus system reserves = 28 cores of
    // quota; two 14-core fillers exhaust it (queued demand counts
    // against headroom even before the first tick admits anything)
    let mut local = Vec::new();
    for _ in 0..2 {
        local.push(
            fed.submit_batch(
                &user,
                "p",
                ResourceVec::cpu_millis(14_000),
                200.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap(),
        );
    }
    let overflow = fed
        .submit_batch(&user, "p", ResourceVec::cpu_millis(14_000), 200.0, PriorityClass::Batch, false)
        .unwrap();
    assert_eq!(
        fed.job_phase(&overflow),
        Some(FederatedJobPhase::PendingReserve),
        "no headroom at home ⇒ the two-phase path"
    );
    // first step: phase 1 grants the claim on the sibling shard
    fed.step(15.0);
    let reserved = fed.job_phase(&overflow).unwrap();
    assert!(
        matches!(reserved, FederatedJobPhase::Reserved { shard: 1, .. }),
        "claim must land on the sibling shard: {reserved:?}"
    );
    assert_eq!(fed.ledger().active_len(), 1);
    // second step: phase 2 consumes it exactly once
    fed.step(15.0);
    assert!(
        matches!(fed.job_phase(&overflow), Some(FederatedJobPhase::Bound { shard: 1, .. })),
        "claim must bind where it was reserved"
    );
    assert_eq!(fed.ledger().active_len(), 0);
    assert_eq!(fed.ledger().stats().bound, 1);
    assert!(fed.ledger().balanced());
    // and the whole burst still drains
    fed.run_for(hours(1.0), 15.0);
    for j in local.iter().chain([&overflow]) {
        assert_eq!(fed.workload_state(j), Some(WorkloadState::Finished), "{j}");
    }
}

// ---------------------------------------------------------------- rebalance

/// Moving a zone between shards: cordon → drain → codec-ship → requota →
/// router flip, with exact free-capacity indexes on both sides and the
/// moved capacity usable by new work.
#[test]
fn rebalance_ships_zone_and_keeps_free_index_exact() {
    let mut fed = Federation::bootstrap(small_config(2)).unwrap();
    assert_eq!(fed.platform(0).node_count(), 2);
    assert_eq!(fed.platform(1).node_count(), 2);
    let (_, nominal0_before) = fed.platform(0).quota_utilization();

    // keep the source shard busy so the drain phase is actually exercised
    let user1 = (0..100)
        .map(|i| format!("user{i:03}"))
        .find(|u| fed.home_shard(u) == 1)
        .unwrap();
    let busy = fed
        .submit_batch(&user1, "p", ResourceVec::cpu_millis(8_000), 120.0, PriorityClass::Batch, false)
        .unwrap();
    fed.run_for(60.0, 15.0);

    // node-01 bootstrapped onto shard 1 (round-robin); move it to shard 0
    assert_eq!(fed.router().route("node-01"), 1);
    fed.request_rebalance("node-01", 0).unwrap();
    assert_eq!(fed.rebalances_pending(), 1);

    // drain + ship completes once the running pod finishes
    fed.run_for(hours(1.0), 15.0);
    assert_eq!(fed.rebalances_pending(), 0, "rebalance must complete");
    assert_eq!(fed.router().route("node-01"), 0, "router must flip the owner");
    assert_eq!(fed.platform(0).node_count(), 3);
    assert_eq!(fed.platform(1).node_count(), 1);
    assert_eq!(fed.metrics().rebalanced_nodes, 1);
    assert_eq!(fed.workload_state(&busy), Some(WorkloadState::Finished));

    // free-capacity indexes exact on both shards after the move
    assert!(fed.check_free_indexes() > 0);

    // quota moved with the node: the target's nominal grew
    let (_, nominal0_after) = fed.platform(0).quota_utilization();
    assert!(
        nominal0_before.fits_in(&nominal0_after)
            && nominal0_before != nominal0_after,
        "shard 0 nominal must grow: {nominal0_before} -> {nominal0_after}"
    );

    // the shipped node is schedulable on its new shard: saturate shard 0
    // beyond its pre-move capacity and drain
    let user0 = (0..100)
        .map(|i| format!("user{i:03}"))
        .find(|u| fed.home_shard(u) == 0)
        .unwrap();
    let jobs: Vec<String> = (0..3)
        .map(|_| {
            fed.submit_batch(
                &user0,
                "p",
                ResourceVec::cpu_millis(12_000),
                100.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap()
        })
        .collect();
    fed.run_for(hours(1.0), 15.0);
    for j in &jobs {
        assert_eq!(fed.workload_state(j), Some(WorkloadState::Finished), "{j}");
    }
}

// ------------------------------------------------------------- merged watch

/// The merged-watch contract: events interleave across shards in time
/// order, the composite cursor resumes exactly, and per-shard compaction
/// surfaces as `Compacted` with list-then-resume recovery.
#[test]
fn merged_watch_interleaves_resumes_and_survives_compaction() {
    let mut cfg = small_config(2);
    cfg.compaction_window = 64; // small ring: churn compacts quickly
    let mut fed = Federation::bootstrap(cfg).unwrap();
    let tokens = fed.login("user001").unwrap();
    let cursor0 = fed.cursor_now();
    assert_eq!(FederatedCursor::decode(&cursor0.encode()).unwrap(), cursor0);

    // one user homed on each shard, so both streams carry pod churn
    let on0 = (0..100)
        .map(|u| format!("user{u:03}"))
        .find(|u| fed.home_shard(u) == 0)
        .unwrap();
    let on1 = (0..100)
        .map(|u| format!("user{u:03}"))
        .find(|u| fed.home_shard(u) == 1)
        .unwrap();
    for u in [&on0, &on1] {
        for i in 0..2 {
            fed.submit_batch(
                u,
                "p",
                ResourceVec::cpu_millis(4_000),
                60.0 + i as f64,
                PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
    }
    fed.run_for(300.0, 15.0);

    let (events, cursor1) = fed.watch_merged(&tokens, ResourceKind::Pod, &cursor0).unwrap();
    assert!(!events.is_empty(), "pod churn must be observable");
    let shards_seen: std::collections::BTreeSet<usize> =
        events.iter().map(|e| e.shard).collect();
    assert_eq!(shards_seen.len(), 2, "both shards must contribute events");
    // merged order: non-decreasing event time
    for w in events.windows(2) {
        assert!(w[0].event.at <= w[1].event.at, "merged stream must be time-ordered");
    }
    // per-shard rv monotonicity within the merged stream
    for s in 0..2 {
        let rvs: Vec<u64> = events
            .iter()
            .filter(|e| e.shard == s)
            .map(|e| e.event.resource_version)
            .collect();
        for w in rvs.windows(2) {
            assert!(w[1] > w[0], "shard {s}: rv regression in merged stream");
        }
    }
    // resuming from the advanced cursor yields nothing until new activity
    let (quiet, cursor2) = fed.watch_merged(&tokens, ResourceKind::Pod, &cursor1).unwrap();
    assert!(quiet.is_empty(), "nothing happened since the cursor advanced");
    assert_eq!(cursor1, cursor2);

    // churn far past the ring window, then resume from the stale cursor:
    // the merged stream must surface the per-shard compaction
    for _ in 0..40 {
        for u in [&on0, &on1] {
            fed.submit_batch(
                u,
                "p",
                ResourceVec::cpu_millis(2_000),
                30.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap();
        }
    }
    fed.run_for(hours(1.0), 30.0);
    assert!(
        matches!(
            fed.watch_merged(&tokens, ResourceKind::Pod, &cursor0),
            Err(ApiError::Compacted(_))
        ),
        "a compacted shard stream must surface on the merged watch"
    );
    // recovery is the single-coordinator contract, federated: re-list,
    // then watch from the fresh composite cursor
    let (pods, fresh) = fed.list_merged(&tokens, ResourceKind::Pod, &Selector::all()).unwrap();
    assert!(!pods.is_empty());
    let (after, _) = fed.watch_merged(&tokens, ResourceKind::Pod, &fresh).unwrap();
    assert!(after.is_empty(), "nothing new since the relist cursor");
}
