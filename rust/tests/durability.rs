//! Crash-tolerance suite: WAL + snapshot restore edge cases (torn tail
//! records, corrupted frames, empty-log snapshots, snapshot compaction
//! right after a restore) and a seeded crash-at-random-tick sweep
//! asserting no workload is lost and accounting balances exactly.

mod common;

use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::hub::profiles::default_catalogue;
use aiinfn::platform::workflow::{RunPhase, StageSpec, LOCAL_SITE};
use aiinfn::platform::Platform;
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::serve::ServingSpec;
use aiinfn::sim::clock::hours;
use aiinfn::sim::traffic::{TrafficEngine, TrafficPattern};

/// A bootstrapped platform with durability on and the given snapshot
/// cadence.
fn durable_platform(snapshot_interval: f64) -> Platform {
    let mut cfg = common::config();
    cfg.durability_enabled = true;
    cfg.durability_snapshot_interval = snapshot_interval;
    Platform::bootstrap(cfg).unwrap()
}

fn submit_one(p: &mut Platform, user: &str, duration: f64) -> String {
    p.submit_batch(
        user,
        "project04",
        ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
        duration,
        PriorityClass::Batch,
        false,
    )
    .unwrap()
}

/// Bootstrap seeds the snapshot *after* the initial inventory is built, so
/// an immediate crash restores from a snapshot with an empty WAL — and the
/// restored platform is byte-equivalent (same resourceVersion, same
/// inventory) and still runs work to completion.
#[test]
fn restore_from_seed_snapshot_with_empty_log() {
    let mut p = durable_platform(900.0);
    assert_eq!(p.wal_len_bytes(), 0, "bootstrap must leave an empty log");
    let rv = p.cluster().resource_version();
    p.crash_and_restore();
    assert_eq!(p.coordinator_restarts(), 1);
    assert_eq!(p.node_count(), 8);
    assert_eq!(p.cluster().resource_version(), rv);
    p.cluster().check_free_index();
    let wl = submit_one(&mut p, "user011", 120.0);
    p.run_for(600.0, 10.0);
    assert_eq!(p.workload_state(&wl), Some(WorkloadState::Finished));
}

/// A crash mid-append leaves a torn tail frame. Replay discards exactly
/// the torn record, the restore still succeeds, the derived free-capacity
/// index matches a brute-force recomputation, and the in-flight workload
/// still drains to Finished.
#[test]
fn torn_wal_tail_is_discarded_and_restore_still_succeeds() {
    let mut p = durable_platform(10_000.0); // no snapshot during the run
    let wl = submit_one(&mut p, "user012", 400.0);
    p.run_for(120.0, 10.0);
    let h = p.wal_handle().unwrap();
    let len = h.borrow().len_bytes();
    assert!(len > 8, "the run must have logged something");
    // tear the last frame mid-record, as a kill mid-write would
    h.borrow_mut().truncate_bytes(len - 3);
    p.crash_and_restore();
    assert_eq!(p.coordinator_restarts(), 1);
    assert_eq!(p.node_count(), 8);
    p.cluster().check_free_index();
    p.run_for(hours(1.0), 10.0);
    assert_eq!(p.workload_state(&wl), Some(WorkloadState::Finished));
}

/// A flipped byte inside a frame fails that frame's CRC: replay stops at
/// the bad frame (reporting it), keeps every record before it, and the
/// restore continues from the shortened log.
#[test]
fn corrupt_wal_byte_stops_replay_at_the_bad_frame() {
    let mut p = durable_platform(10_000.0);
    let wl = submit_one(&mut p, "user013", 400.0);
    p.run_for(120.0, 10.0);
    let h = p.wal_handle().unwrap();
    let appended = h.borrow().appended();
    let len = h.borrow().len_bytes();
    h.borrow_mut().corrupt_byte(len - 20);
    let (records, warn) = h.borrow().replay();
    assert!(warn.is_some(), "corruption must be reported, not ignored");
    assert!((records.len() as u64) < appended, "the bad frame must be dropped");
    p.crash_and_restore();
    assert_eq!(p.coordinator_restarts(), 1);
    p.cluster().check_free_index();
    p.run_for(hours(1.0), 10.0);
    assert_eq!(p.workload_state(&wl), Some(WorkloadState::Finished));
}

/// Restore replays the WAL but deliberately does not clear it (a second
/// crash before the next snapshot must replay the same tail). The next
/// snapshot interval then compacts the replayed log into a fresh snapshot,
/// and a second crash restores from *that* — the
/// restore → compact → crash → restore cycle is stable.
#[test]
fn restore_then_immediate_compaction_then_second_crash() {
    let mut p = durable_platform(60.0);
    let wl = submit_one(&mut p, "user014", 400.0);
    p.run_for(90.0, 10.0);
    p.crash_and_restore();
    assert_eq!(p.coordinator_restarts(), 1);
    assert!(p.wal_len_bytes() > 0, "restore must keep the log for a repeat crash");
    // the 60 s snapshot cadence elapses right after the restore,
    // compacting the replayed log into a fresh snapshot
    p.run_for(120.0, 10.0);
    p.crash_and_restore();
    assert_eq!(p.coordinator_restarts(), 2);
    p.cluster().check_free_index();
    p.run_for(hours(1.0), 10.0);
    assert_eq!(p.workload_state(&wl), Some(WorkloadState::Finished));
}

/// Kill the coordinator mid-DAG — some stages done, a gang in flight, an
/// offloaded stage running at a federation site — and let the restored
/// coordinator finish the run. Workflow state (including per-run logs) is
/// checkpointed into control records every tick and gang admission passes
/// are WAL-replayed, so the interrupted run must converge to a workflow
/// trace byte-identical to an uninterrupted twin.
#[test]
fn mid_dag_coordinator_kill_converges_byte_identically() {
    const GB: u64 = 1 << 30;
    let stage = |name: &str,
                 cpu_millis: i64,
                 pods: u32,
                 duration: f64,
                 inputs: &[&str],
                 outputs: &[(&str, u64)],
                 offloadable: bool| StageSpec {
        name: name.to_string(),
        requests: ResourceVec::cpu_millis(cpu_millis).with(MEMORY, 4 << 30),
        pods,
        duration,
        inputs: inputs.iter().map(|s| s.to_string()).collect(),
        outputs: outputs.iter().map(|(n, s)| (n.to_string(), *s)).collect(),
        offloadable,
    };
    let build = || {
        let mut p = durable_platform(120.0);
        p.create_dataset("dur-calib", "user041", GB, vec![LOCAL_SITE.into()]).unwrap();
        p.create_dataset("dur-raw", "user041", 150 * GB, vec!["INFN-T1".into()]).unwrap();
        p.create_workflow_run(
            "wf-durable",
            "user041",
            "project04",
            PriorityClass::Batch,
            "workflow",
            vec![
                stage("prep", 4000, 2, 120.0, &["dur-calib"], &[("dur-clean", 2 * GB)], false),
                stage("train", 8000, 3, 360.0, &["dur-raw"], &[("dur-model", GB)], true),
                stage(
                    "merge",
                    4000,
                    1,
                    120.0,
                    &["dur-clean", "dur-model"],
                    &[("dur-merged", GB)],
                    true,
                ),
                stage("publish", 2000, 1, 60.0, &["dur-merged"], &[("dur-bundle", GB / 4)], false),
            ],
        )
        .unwrap();
        p
    };

    // twin A: uninterrupted
    let mut a = build();
    a.run_for(3600.0, 15.0);
    assert_eq!(a.workflow_run("wf-durable").unwrap().phase, RunPhase::Succeeded);

    // twin B: killed at a tick boundary mid-DAG (prep done, train running
    // remotely), restored, then run for the remaining horizon
    let mut b = build();
    b.run_for(405.0, 15.0);
    b.crash_and_restore();
    assert_eq!(b.coordinator_restarts(), 1);
    b.run_for(3195.0, 15.0);

    let run_b = b.workflow_run("wf-durable").unwrap();
    assert_eq!(run_b.phase, RunPhase::Succeeded, "restored run log:\n{}", run_b.trace());
    assert_eq!(
        a.workflow_trace(),
        b.workflow_trace(),
        "the interrupted run must converge to the uninterrupted trace byte-for-byte"
    );
    assert_eq!(a.metrics().workflow_bytes_staged, b.metrics().workflow_bytes_staged);
    assert_eq!(a.metrics().workflow_stages_completed, b.metrics().workflow_stages_completed);
    let (used, _) = b.quota_utilization();
    assert!(used.is_empty(), "leaked quota {used}");
    b.cluster().check_free_index();
}

/// Crash at a seed-derived point of the campaign, restore, and run to the
/// end: every submitted workload still reaches Finished, completion
/// accounting balances exactly, quota drains to zero, and the rebuilt
/// free-capacity index mirrors the free map.
#[test]
fn seeded_crash_sweep_loses_no_work_and_balances_accounting() {
    let base = common::test_seed();
    for i in 0..8u64 {
        let mut p = durable_platform(120.0);
        let n = 6usize;
        let wls: Vec<String> = (0..n)
            .map(|j| {
                p.submit_batch(
                    &format!("user{:03}", (i as usize * 7 + j) % 78),
                    "project04",
                    ResourceVec::cpu_millis(8000).with(MEMORY, 8 << 30),
                    300.0,
                    PriorityClass::Batch,
                    j % 2 == 0,
                )
                .unwrap()
            })
            .collect();
        let crash_at =
            40.0 + (base.wrapping_mul(2_654_435_761).wrapping_add(i * 97) % 900) as f64;
        p.run_for(crash_at, 15.0);
        p.crash_and_restore();
        assert_eq!(p.coordinator_restarts(), 1, "run {i}");
        p.run_for(hours(2.0), 15.0);
        for w in &wls {
            assert_eq!(
                p.workload_state(w),
                Some(WorkloadState::Finished),
                "run {i}, crash at {crash_at}: workload {w} lost"
            );
        }
        let m = p.metrics();
        assert_eq!(
            m.local_completions + m.remote_completions + m.terminal_failures,
            n as u64,
            "run {i}, crash at {crash_at}: {m:?}"
        );
        let (used, _) = p.quota_utilization();
        assert!(used.is_empty(), "run {i}, crash at {crash_at}: leaked quota {used}");
        p.cluster().check_free_index();
    }
}

/// The crash sweep again with every moving part of the platform in flight
/// at the kill: a serving fleet under live traffic, an interactive
/// session, a workflow DAG mid-execution, and batch jobs — so the restore
/// path is exercised against workloads of every API kind at once. The
/// restored coordinator finishes all of it: batch drains, the DAG
/// succeeds, serving request accounting still balances, and after
/// teardown quota drains to zero.
#[test]
fn seeded_crash_sweep_survives_serving_session_and_workflow_traffic() {
    const GB: u64 = 1 << 30;
    let base = common::test_seed();
    for i in 0..4u64 {
        let mut p = durable_platform(120.0);
        // serving: one CPU fleet under flat traffic
        let mut engine = TrafficEngine::new(base.wrapping_add(i));
        engine.add(0.0, TrafficPattern::flat("dur-serve", 20.0));
        p.set_traffic(engine);
        p.create_inference_server(ServingSpec {
            name: "dur-serve".to_string(),
            user: "user001".to_string(),
            project: "project01".to_string(),
            model: "deepmet".to_string(),
            requests: ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
            min_replicas: 1,
            max_replicas: 3,
            latency_slo: 0.5,
            max_batch: 8,
            batch_window: 0.02,
            service_time: 0.08,
            queue_depth: 256,
            queue: "serving".to_string(),
        })
        .unwrap();
        // an interactive session
        let profile =
            default_catalogue().into_iter().find(|x| x.name == "cpu-small").unwrap();
        let sid = p.spawn_session("user042", &profile).unwrap();
        // a two-stage workflow DAG
        let raw = format!("dur-sweep-raw-{i}");
        let clean = format!("dur-sweep-clean-{i}");
        let run = format!("wf-sweep-{i}");
        p.create_dataset(&raw, "user041", 2 * GB, vec![LOCAL_SITE.into()]).unwrap();
        p.create_workflow_run(
            &run,
            "user041",
            "project04",
            PriorityClass::Batch,
            "workflow",
            vec![
                StageSpec {
                    name: "prep".to_string(),
                    requests: ResourceVec::cpu_millis(4000).with(MEMORY, 4 << 30),
                    pods: 1,
                    duration: 180.0,
                    inputs: vec![raw.clone()],
                    outputs: vec![(clean.clone(), GB)],
                    offloadable: false,
                },
                StageSpec {
                    name: "fit".to_string(),
                    requests: ResourceVec::cpu_millis(4000).with(MEMORY, 4 << 30),
                    pods: 2,
                    duration: 240.0,
                    inputs: vec![clean.clone()],
                    outputs: vec![(format!("dur-sweep-out-{i}"), GB / 2)],
                    offloadable: false,
                },
            ],
        )
        .unwrap();
        // and plain batch alongside
        let wls: Vec<String> =
            (0..4).map(|j| submit_one(&mut p, &format!("user{:03}", 20 + j), 300.0)).collect();

        let crash_at =
            60.0 + (base.wrapping_mul(2_654_435_761).wrapping_add(i * 131) % 600) as f64;
        p.run_for(crash_at, 15.0);
        p.crash_and_restore();
        assert_eq!(p.coordinator_restarts(), 1, "run {i}");
        p.run_for(hours(2.0), 15.0);

        for w in &wls {
            assert_eq!(
                p.workload_state(w),
                Some(WorkloadState::Finished),
                "run {i}, crash at {crash_at}: batch workload {w} lost"
            );
        }
        let wf = p.workflow_run(&run).unwrap();
        assert_eq!(
            wf.phase,
            RunPhase::Succeeded,
            "run {i}, crash at {crash_at}: workflow log:\n{}",
            wf.trace()
        );
        let s = p.serving_state("dur-serve").unwrap();
        assert!(s.total_requests > 0, "run {i}: traffic must have arrived");
        assert_eq!(
            s.total_requests,
            s.completed_requests + s.failed_requests + s.queued(),
            "run {i}: serving accounting must balance across the crash"
        );
        // tear the long-lived workloads down so quota can drain (the
        // session may already have been idle-culled during the horizon)
        p.delete_inference_server("dur-serve").unwrap();
        let _ = p.stop_session(&sid, "sweep teardown");
        p.run_for(120.0, 15.0);
        let (used, _) = p.quota_utilization();
        assert!(used.is_empty(), "run {i}, crash at {crash_at}: leaked quota {used}");
        p.cluster().check_free_index();
    }
}

/// Shard-targeted kill: a 2-shard federation under a durability campaign
/// where shard 1's coordinator is crash-restored mid-run while shard 0
/// never stops ticking. Shard 1 loses no work; shard 0's transition log
/// is byte-identical to a twin federation that was never killed.
#[test]
fn shard_kill_mid_campaign_leaves_other_shards_ticking() {
    use aiinfn::platform::Federation;
    use aiinfn::sim::chaos::Fault;

    let run = |kill: bool| -> (Federation, Vec<String>) {
        let mut cfg = common::config();
        cfg.shard_count = 2;
        cfg.durability_enabled = true;
        cfg.durability_snapshot_interval = 120.0;
        let mut fed = Federation::bootstrap(cfg).unwrap();
        if kill {
            fed.inject_fault(700.0, Fault::CoordinatorCrash { shard: Some(1) });
        }
        // load on both shards: one user homed on each
        let on0 = (0..100)
            .map(|u| format!("user{u:03}"))
            .find(|u| fed.home_shard(u) == 0)
            .unwrap();
        let on1 = (0..100)
            .map(|u| format!("user{u:03}"))
            .find(|u| fed.home_shard(u) == 1)
            .unwrap();
        let mut jobs = Vec::new();
        for u in [&on0, &on1] {
            for _ in 0..4 {
                jobs.push(
                    fed.submit_batch(
                        u,
                        "project04",
                        ResourceVec::cpu_millis(8000).with(MEMORY, 8 << 30),
                        300.0,
                        PriorityClass::Batch,
                        false,
                    )
                    .unwrap(),
                );
            }
        }
        fed.run_for(hours(1.0), 15.0);
        (fed, jobs)
    };

    let (clean, clean_jobs) = run(false);
    let (killed, killed_jobs) = run(true);

    assert_eq!(clean.platform(0).coordinator_restarts(), 0);
    assert_eq!(clean.platform(1).coordinator_restarts(), 0);
    assert_eq!(killed.platform(0).coordinator_restarts(), 0, "shard 0 never crashed");
    assert_eq!(killed.platform(1).coordinator_restarts(), 1, "the targeted kill fired");
    assert_eq!(killed.metrics().shard_crashes, 1);

    // no workload lost in either federation
    for j in &clean_jobs {
        assert_eq!(clean.workload_state(j), Some(WorkloadState::Finished), "clean {j}");
    }
    for j in &killed_jobs {
        assert_eq!(killed.workload_state(j), Some(WorkloadState::Finished), "killed {j}");
    }
    for fed in [&clean, &killed] {
        for s in 0..2 {
            let (used, _) = fed.platform(s).quota_utilization();
            assert!(used.is_empty(), "shard {s} leaked quota {used}");
        }
        assert!(fed.check_free_indexes() > 0);
    }

    // the untouched shard's transition log is byte-identical across the
    // kill (store events + workload transitions)
    let trace = |p: &Platform| -> String {
        let mut out = String::new();
        {
            let st = p.cluster();
            for ev in st.events() {
                out.push_str(&format!(
                    "{:10.3} {:?} {} {}\n",
                    ev.at, ev.kind, ev.object, ev.message
                ));
            }
        }
        for t in p.workload_transitions_since(0) {
            out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
        }
        out
    };
    assert_eq!(
        trace(clean.platform(0)),
        trace(killed.platform(0)),
        "shard 0 must not notice shard 1's crash"
    );
    // and the killed shard converges to its own uninterrupted twin
    assert_eq!(
        trace(clean.platform(1)),
        trace(killed.platform(1)),
        "shard 1 must restore to the uninterrupted trace"
    );
}
