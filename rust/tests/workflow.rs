//! Federated-workflow integration suite: the end-to-end DAG walked by the
//! reconciler alone (gang-scheduled multi-pod stages, InterLink offload
//! with stage-in/stage-out through the object store), stage retry after a
//! chaos-injected remote failure, gang-admission deadlock freedom under
//! quota pressure (8-seed sweep), transfer-cost placement decisions, API
//! verb round-trips for `WorkflowRun`/`Dataset`, and golden-trace
//! determinism with the workflow engine live.

mod common;

use aiinfn::api::{
    ApiError, ApiObject, Condition, DatasetResource, ResourceKind, Selector, StageTemplate,
    WorkflowRunResource,
};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::workflow::{RunPhase, StagePhase, StageSpec, LOCAL_SITE};
use aiinfn::queue::kueue::PriorityClass;
use aiinfn::sim::chaos::{ChaosEngine, Fault};
use aiinfn::sim::clock::hours;

const GB: u64 = 1 << 30;

fn stage(
    name: &str,
    cpu_millis: i64,
    pods: u32,
    duration: f64,
    inputs: &[&str],
    outputs: &[(&str, u64)],
    offloadable: bool,
) -> StageSpec {
    StageSpec {
        name: name.to_string(),
        requests: ResourceVec::cpu_millis(cpu_millis).with(MEMORY, 4 << 30),
        pods,
        duration,
        inputs: inputs.iter().map(|s| s.to_string()).collect(),
        outputs: outputs.iter().map(|(n, s)| (n.to_string(), *s)).collect(),
        offloadable,
    }
}

fn stage_template(
    name: &str,
    cpu_millis: i64,
    pods: u32,
    duration: f64,
    inputs: &[&str],
    outputs: &[(&str, u64)],
    offloadable: bool,
) -> StageTemplate {
    StageTemplate {
        name: name.to_string(),
        requests: ResourceVec::cpu_millis(cpu_millis).with(MEMORY, 4 << 30),
        pods,
        duration,
        inputs: inputs.iter().map(|s| s.to_string()).collect(),
        outputs: outputs.iter().map(|(n, s)| (n.to_string(), *s)).collect(),
        offloadable,
    }
}

// --------------------------------------------------------------- end-to-end

/// The tentpole scenario, driven through the API server and the workflow
/// reconciler only: six stages over datasets pinned at two sites. The
/// training stage is a 4-pod gang whose 200 GB input lives only at
/// INFN-T1, so placement offloads it (stage-in pulls the 1 GB calibration
/// set to the site, stage-out ships the model back); everything downstream
/// runs locally because its inputs are already home.
#[test]
fn six_stage_two_site_dag_completes_via_reconciler() {
    let mut api = common::api();
    let token = api.login("user010").unwrap();

    for (name, size, site) in
        [("calib", GB, LOCAL_SITE), ("raw-t1", 200 * GB, "INFN-T1")]
    {
        let d = DatasetResource::request(name, "user010", size, vec![site.to_string()]);
        api.create(&token, &ApiObject::Dataset(d)).unwrap();
    }

    let stages = vec![
        stage_template("prep", 4000, 2, 120.0, &["calib"], &[("prep-out", 2 * GB)], false),
        stage_template("train", 8000, 4, 300.0, &["raw-t1", "calib"], &[("model-a", GB)], true),
        stage_template(
            "merge",
            4000,
            1,
            120.0,
            &["prep-out", "model-a"],
            &[("merged", GB)],
            true,
        ),
        stage_template("eval-a", 2000, 1, 60.0, &["merged"], &[("report-a", GB / 8)], true),
        stage_template("eval-b", 2000, 1, 60.0, &["merged"], &[("report-b", GB / 8)], true),
        stage_template(
            "publish",
            1000,
            1,
            60.0,
            &["report-a", "report-b"],
            &[("bundle", GB / 4)],
            false,
        ),
    ];
    let req = WorkflowRunResource::request("lhcb-train", "user010", "project03", stages);
    let created = api.create(&token, &ApiObject::WorkflowRun(req)).unwrap();
    let view = created.as_workflow_run().unwrap();
    assert_eq!(view.queue, "workflow", "admission must default the workflow queue");
    assert_eq!(view.priority, "batch", "admission must default the priority");
    assert_eq!(view.phase, "Pending");

    // reconciler only from here: no direct platform verbs
    api.run_for(3600.0, 15.0);

    let got = api.get(&token, ResourceKind::WorkflowRun, "lhcb-train").unwrap();
    let got = got.as_workflow_run().unwrap();
    assert_eq!(got.phase, "Succeeded", "stages: {:?}", got.stage_status);
    assert_eq!(got.stages_completed, 6);
    let by_name = |n: &str| got.stage_status.iter().find(|s| s.name == n).unwrap();
    assert_eq!(by_name("train").site, "INFN-T1", "the T1-pinned input must pull training remote");
    assert_eq!(by_name("prep").site, LOCAL_SITE);
    assert_eq!(by_name("merge").site, LOCAL_SITE, "staged-back model must keep merge local");
    for s in &got.stage_status {
        assert_eq!(s.phase, "Succeeded", "stage {}: {:?}", s.name, s);
        assert_eq!(s.retries, 0, "stage {}", s.name);
    }
    // stage-in (calib → T1) + stage-out (model-a → local) moved real bytes
    assert!(
        got.bytes_staged >= 2 * GB,
        "stage-in + stage-out must be accounted: {}",
        got.bytes_staged
    );

    let p = api.platform();
    let m = p.metrics();
    assert_eq!(m.workflow_stages_completed, 6);
    assert!(m.workflow_offloaded_stages >= 1, "training must run through InterLink");
    assert_eq!(m.workflow_gangs_bound, 6, "one gang per stage, no retries");
    assert!(m.workflow_gang_wait_total >= 0.0);
    assert_eq!(m.workflow_bytes_staged, got.bytes_staged);

    // outputs registered as datasets at their execution sites; the
    // offloaded model was staged back to local storage
    let model = api.get(&token, ResourceKind::Dataset, "model-a").unwrap();
    let model = model.as_dataset().unwrap();
    assert!(model.locations.iter().any(|l| l == "INFN-T1"), "{:?}", model.locations);
    assert!(model.locations.iter().any(|l| l == LOCAL_SITE), "{:?}", model.locations);
    let listed = api
        .list(&token, ResourceKind::Dataset, &Selector::labels("app=dataset").unwrap())
        .unwrap();
    assert!(listed.len() >= 8, "inputs + registered stage outputs: {}", listed.len());

    // everything drained: no leaked gang quota, all pods terminal
    let (used, _) = p.quota_utilization();
    assert!(used.is_empty(), "leaked quota {used}");
    let phases = p.pod_phase_counts();
    assert_eq!(phases.get("succeeded"), Some(&10), "{phases:?}");
}

// ------------------------------------------------------------- stage retry

/// A chaos-killed remote stage retries as a fresh pod incarnation without
/// re-running completed independent stages: the side branch finishes
/// before the remote failure lands, keeps its result, and the run still
/// converges with exactly one retry on the books.
#[test]
fn failed_stage_retries_without_rerunning_completed_stages() {
    let mut p = common::platform();
    let mut chaos = ChaosEngine::new();
    // kill the first remote job that shows up on INFN-T1
    chaos.inject(1.0, Fault::RemoteJobFailures { site: "INFN-T1".into(), count: 1 });
    p.set_chaos(chaos);

    p.create_dataset("bulk", "user020", 400 * GB, vec!["INFN-T1".into()]).unwrap();
    let stages = vec![
        // pinned-remote input → placement picks INFN-T1 deterministically
        stage("remote-train", 8000, 2, 240.0, &["bulk"], &[("model", GB)], true),
        // independent local branch, done long before the remote failure
        stage("side", 2000, 1, 60.0, &[], &[("side-out", GB / 8)], false),
        stage("final", 2000, 1, 60.0, &["model", "side-out"], &[("result", GB / 8)], false),
    ];
    p.create_workflow_run("wf-retry", "user020", "project04", PriorityClass::Batch, "workflow", stages)
        .unwrap();
    p.run_for(3600.0, 15.0);

    let run = p.workflow_run("wf-retry").unwrap();
    assert_eq!(run.phase, RunPhase::Succeeded, "log:\n{}", run.trace());
    let idx = |n: &str| run.stages.iter().position(|s| s.name == n).unwrap();
    let train = &run.stage_states[idx("remote-train")];
    assert_eq!(train.phase, StagePhase::Succeeded);
    assert_eq!(train.retries, 1, "exactly one chaos kill, one retry: {}", run.trace());
    assert_eq!(train.incarnation, 2, "the retry must be a fresh incarnation");
    assert_eq!(train.site, "INFN-T1", "the data hasn't moved, so neither has placement");
    let side = &run.stage_states[idx("side")];
    assert_eq!(side.phase, StagePhase::Succeeded);
    assert_eq!(side.retries, 0);
    assert_eq!(side.incarnation, 1, "completed independent stages must not re-run");

    let m = p.metrics();
    assert_eq!(m.workflow_stage_retries, 1);
    assert_eq!(m.workflow_stages_completed, 3, "each stage counted once");
    assert_eq!(m.terminal_failures, 0);
    let (used, _) = p.quota_utilization();
    assert!(used.is_empty(), "failed incarnation must release its gang quota: {used}");
}

// --------------------------------------------------- gang deadlock freedom

/// Two gangs whose combined reservations exceed the quota left by a wall
/// of batch fillers: both reserve partially, stall, release through the
/// gang timeout, back off staggered, and converge once the fillers drain —
/// one runs, then the other. No workload is lost and quota drains to zero,
/// across 8 derived seeds.
#[test]
fn competing_gangs_converge_without_deadlock() {
    let base = common::test_seed();
    for i in 0..8u64 {
        let seed = base.wrapping_mul(131).wrapping_add(i);
        let mut p = common::platform();
        // fillers soak ~960 cores of the ~1080-core cohort quota for long
        // enough that both gangs hit the reserve timeout repeatedly
        let filler_duration = 700.0 + (seed % 5) as f64 * 60.0;
        common::submit_cpu_batch(&mut p, 60, 16_000, filler_duration, true);
        p.run_for(30.0, 15.0);

        let dur_a = 200.0 + (seed % 4) as f64 * 50.0;
        let dur_b = 200.0 + (seed % 3) as f64 * 50.0;
        // each gang alone fits the 448-core local cluster; together they
        // need 832 cores — far beyond both the leftover quota (~120) and
        // the hardware
        p.create_workflow_run(
            "gang-a",
            "user030",
            "project05",
            PriorityClass::Batch,
            "workflow",
            vec![stage("burst", 16_000, 26, dur_a, &[], &[("a-out", GB)], false)],
        )
        .unwrap();
        p.create_workflow_run(
            "gang-b",
            "user031",
            "project05",
            PriorityClass::Batch,
            "workflow",
            vec![stage("burst", 8_000, 52, dur_b, &[], &[("b-out", GB)], false)],
        )
        .unwrap();
        p.run_for(hours(2.5), 15.0);

        for name in ["gang-a", "gang-b"] {
            let run = p.workflow_run(name).unwrap();
            assert_eq!(
                run.phase,
                RunPhase::Succeeded,
                "seed {seed}: {name} must converge; log:\n{}",
                run.trace()
            );
        }
        let m = p.metrics();
        assert_eq!(m.workflow_gangs_bound, 2, "seed {seed}");
        assert!(
            m.workflow_gang_wait_total >= p.config.workflow_gang_reserve_timeout,
            "seed {seed}: the gangs must actually have waited through the \
             reserve timeout (waited {:.0}s total)",
            m.workflow_gang_wait_total
        );
        assert_eq!(m.terminal_failures, 0, "seed {seed}");
        let (used, _) = p.quota_utilization();
        assert!(used.is_empty(), "seed {seed}: leaked quota {used}");
        p.cluster().check_free_index();
    }
}

// -------------------------------------------------- transfer-cost placement

/// A small local dataset keeps an offloadable stage local: the transfer
/// cost of moving it anywhere is positive while the local score is zero.
#[test]
fn small_local_dataset_keeps_stage_local() {
    let mut p = common::platform();
    p.create_dataset("small", "user001", GB, vec![LOCAL_SITE.into()]).unwrap();
    p.create_workflow_run(
        "wf-local",
        "user001",
        "project01",
        PriorityClass::Batch,
        "workflow",
        vec![stage("crunch", 4000, 1, 120.0, &["small"], &[("out", GB)], true)],
    )
    .unwrap();
    p.run_for(900.0, 15.0);

    let run = p.workflow_run("wf-local").unwrap();
    assert_eq!(run.phase, RunPhase::Succeeded, "{}", run.trace());
    assert_eq!(run.stage_states[0].site, LOCAL_SITE);
    assert_eq!(run.bytes_staged, 0, "a local stage moves nothing");
    assert_eq!(p.metrics().workflow_offloaded_stages, 0);
}

/// With the local cluster saturated by non-offloadable fillers, the queue
/// wait penalty dominates the (small) transfer cost and the stage offloads
/// to the nearest healthy site, staging its input in and its output back.
#[test]
fn queue_wait_pressure_offloads_stage_despite_transfer_cost() {
    let mut p = common::platform();
    // 28 × 16 cores = 448: every local core spoken for, for a long time
    common::submit_cpu_batch(&mut p, 28, 16_000, 3000.0, false);
    p.run_for(60.0, 15.0);

    p.create_dataset("near", "user002", GB, vec![LOCAL_SITE.into()]).unwrap();
    p.create_workflow_run(
        "wf-off",
        "user002",
        "project01",
        PriorityClass::Batch,
        "workflow",
        vec![stage("crunch", 4000, 1, 120.0, &["near"], &[("out", GB)], true)],
    )
    .unwrap();
    p.run_for(1800.0, 15.0);

    let run = p.workflow_run("wf-off").unwrap();
    assert_eq!(run.phase, RunPhase::Succeeded, "{}", run.trace());
    assert_eq!(
        run.stage_states[0].site, "INFN-T1",
        "queue wait (600 s penalty) must beat the 0.8 s transfer to the nearest site"
    );
    // 1 GB staged in to the site, 1 GB of output staged back
    assert_eq!(run.bytes_staged, 2 * GB);
    assert_eq!(p.metrics().workflow_offloaded_stages, 1);
}

// ------------------------------------------------------------ golden trace

/// One federated-workflow scenario rendered as a text blob: per-run
/// transition logs, cluster events, Kueue workload transitions. Stage
/// durations and dataset sizes derive from the seed so distinct seeds
/// produce genuinely different schedules.
fn workflow_golden_trace(seed: u64) -> String {
    let mut p = common::platform();
    let hot = (50 + seed % 97) * GB;
    let d = 100.0 + (seed % 7) as f64 * 20.0;
    p.create_dataset("hot", "user005", hot, vec!["INFN-T1".into()]).unwrap();
    p.create_dataset("cold", "user005", GB, vec![LOCAL_SITE.into()]).unwrap();
    p.create_workflow_run(
        "wf-golden",
        "user005",
        "project02",
        PriorityClass::Batch,
        "workflow",
        vec![
            stage("prep", 4000, 2, d, &["cold"], &[("clean", 2 * GB)], false),
            stage("train", 8000, 3, 2.0 * d, &["hot"], &[("model", GB)], true),
            stage("merge", 4000, 1, d, &["clean", "model"], &[("merged", GB)], true),
            stage("publish", 2000, 1, d / 2.0, &["merged"], &[("bundle", GB / 4)], false),
        ],
    )
    .unwrap();
    common::submit_cpu_batch(&mut p, 2 + (seed % 5) as usize, 8000, 300.0, true);
    p.run_for(3600.0, 15.0);

    let mut out = String::new();
    out.push_str(&p.workflow_trace());
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    out
}

/// Same seed ⇒ byte-identical trace with the workflow engine live;
/// different seed ⇒ different DAG timings, different trace.
#[test]
fn workflow_golden_trace_same_seed_is_byte_identical() {
    let seed = common::test_seed();
    let a = workflow_golden_trace(seed);
    let b = workflow_golden_trace(seed);
    assert!(a.contains("wf/wf-golden"), "trace must include workflow transitions");
    assert!(a.contains("gang"), "trace must include gang submissions");
    assert_eq!(a, b, "same seed must reproduce the workflow trace byte-for-byte");
    let c = workflow_golden_trace(seed.wrapping_add(1));
    assert_ne!(a, c, "different seeds must produce different traces");
}

// --------------------------------------------------------------- API verbs

#[test]
fn workflow_api_verbs_roundtrip() {
    let mut api = common::api();
    let token = api.login("user012").unwrap();

    // datasets first: the run's external input must exist
    let ds = DatasetResource::request("api-raw", "user012", 10 * GB, vec!["ReCaS-Bari".into()]);
    let created = api.create(&token, &ApiObject::Dataset(ds.clone())).unwrap();
    let view = created.as_dataset().unwrap();
    assert_eq!(view.phase, "Ready");
    assert_eq!(view.locations, vec!["ReCaS-Bari".to_string()]);
    assert!(matches!(
        api.create(&token, &ApiObject::Dataset(ds.clone())),
        Err(ApiError::Conflict(_))
    ));

    let req = WorkflowRunResource::request(
        "api-wf",
        "user012",
        "project06",
        vec![stage_template("only", 2000, 1, 60.0, &["api-raw"], &[("api-out", GB)], true)],
    );
    let other = api.login("user013").unwrap();
    assert!(matches!(
        api.create(&other, &ApiObject::WorkflowRun(req.clone())),
        Err(ApiError::Forbidden(_))
    ));
    let created = api.create(&token, &ApiObject::WorkflowRun(req.clone())).unwrap();
    assert_eq!(created.as_workflow_run().unwrap().queue, "workflow");
    assert!(matches!(
        api.create(&token, &ApiObject::WorkflowRun(req)),
        Err(ApiError::Conflict(_))
    ));

    // a run whose external input is not a registered dataset is rejected
    let orphan = WorkflowRunResource::request(
        "api-orphan",
        "user012",
        "project06",
        vec![stage_template("only", 2000, 1, 60.0, &["no-such-data"], &[], false)],
    );
    assert!(api.create(&token, &ApiObject::WorkflowRun(orphan)).is_err());

    // a cyclic stage graph is rejected by admission
    let cyclic = WorkflowRunResource::request(
        "api-cycle",
        "user012",
        "project06",
        vec![
            stage_template("a", 2000, 1, 60.0, &["x"], &[("y", GB)], false),
            stage_template("b", 2000, 1, 60.0, &["y"], &[("x", GB)], false),
        ],
    );
    assert!(matches!(
        api.create(&token, &ApiObject::WorkflowRun(cyclic)),
        Err(ApiError::Invalid(_))
    ));

    // the spec is immutable once submitted; labels still move
    let got = api.get(&token, ResourceKind::WorkflowRun, "api-wf").unwrap();
    let mut bad = got.as_workflow_run().unwrap().clone();
    bad.stages[0].duration = 999.0;
    assert!(matches!(
        api.update(&token, &ApiObject::WorkflowRun(bad)),
        Err(ApiError::Invalid(_))
    ));
    let mut relabel = got.as_workflow_run().unwrap().clone();
    relabel.metadata.labels.insert("team".into(), "flav".into());
    let updated = api.update(&token, &ApiObject::WorkflowRun(relabel)).unwrap();
    assert_eq!(
        updated.as_workflow_run().unwrap().metadata.labels.get("team"),
        Some(&"flav".to_string())
    );

    // status subresource: conditions only
    let mut st = updated.as_workflow_run().unwrap().clone();
    st.conditions = vec![Condition::new("Paused", true, "ManualFlag", "ops note", 0.0)];
    let after = api.update_status(&token, &ApiObject::WorkflowRun(st)).unwrap();
    assert_eq!(after.as_workflow_run().unwrap().conditions.len(), 1);

    // label-selector list sees the run
    let listed = api
        .list(&token, ResourceKind::WorkflowRun, &Selector::labels("app=workflow").unwrap())
        .unwrap();
    assert_eq!(listed.len(), 1);

    // run it to completion, then delete: only the owner may
    api.run_for(900.0, 15.0);
    let done = api.get(&token, ResourceKind::WorkflowRun, "api-wf").unwrap();
    assert_eq!(done.as_workflow_run().unwrap().phase, "Succeeded");
    assert!(matches!(
        api.delete(&other, ResourceKind::WorkflowRun, "api-wf"),
        Err(ApiError::Forbidden(_))
    ));
    api.delete(&token, ResourceKind::WorkflowRun, "api-wf").unwrap();
    api.run_for(60.0, 15.0);
    assert!(matches!(
        api.get(&token, ResourceKind::WorkflowRun, "api-wf"),
        Err(ApiError::NotFound(_))
    ));
    assert!(api.platform().workflow_run("api-wf").is_none());

    // deleting a dataset drops the record on the next tick
    api.delete(&token, ResourceKind::Dataset, "api-raw").unwrap();
    api.run_for(60.0, 15.0);
    assert!(api.platform().dataset("api-raw").is_none());
}
