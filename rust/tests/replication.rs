//! Coordinator high-availability suite: hot-standby promotion under
//! leader kills (byte-identical convergence against an uninterrupted
//! twin), split-brain epoch fencing after a network partition, a seeded
//! kill sweep asserting zero acknowledged-work loss, bounded loss under a
//! configured shipping holdback, and clean aborts on damaged replica
//! state.

mod common;

use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::platform::Platform;
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::sim::chaos::{ChaosPlan, Fault};
use aiinfn::sim::clock::hours;

/// A bootstrapped platform with hot-standby replication on (which implies
/// durability) and the given lease / holdback / snapshot-cadence knobs.
fn replicated_platform(lease: f64, ship_lag: u64, snapshot_interval: f64) -> Platform {
    let mut cfg = common::config();
    cfg.replication_enabled = true;
    cfg.replication_lease_seconds = lease;
    cfg.replication_max_ship_lag = ship_lag;
    cfg.durability_snapshot_interval = snapshot_interval;
    Platform::bootstrap(cfg).unwrap()
}

/// An empty chaos schedule (all rates zero) so tests can pin individual
/// leader faults at exact times.
fn quiet_plan(seed: u64) -> ChaosPlan {
    ChaosPlan {
        seed,
        horizon: 3600.0,
        site_outages_per_hour: 0.0,
        wire_faults_per_hour: 0.0,
        remote_job_failures_per_hour: 0.0,
        node_flaps_per_hour: 0.0,
        gpu_degrades_per_hour: 0.0,
        ..Default::default()
    }
}

// ------------------------------------------------- failover convergence

/// One HA campaign under mixed chaos, rendered as the transition blob the
/// durability suite compares (chaos log excluded: the killed run
/// legitimately records the extra leader-kill entries).
fn ha_trace(seed: u64, kill: bool) -> (String, u64) {
    let mut cfg = common::config();
    cfg.replication_enabled = true;
    // shorter than the 15 s tick: a kill drained at a tick boundary finds
    // the lease already expired and promotes in that same tick, so the
    // control plane never skips a dispatch
    cfg.replication_lease_seconds = 10.0;
    cfg.durability_snapshot_interval = 300.0;
    let mut p = Platform::bootstrap(cfg).unwrap();
    let plan = ChaosPlan {
        seed,
        horizon: 1200.0,
        site_outages_per_hour: 2.0,
        wire_faults_per_hour: 4.0,
        remote_job_failures_per_hour: 2.0,
        node_flaps_per_hour: 1.0,
        // drawn after every other fault family in generate(): enabling
        // kills leaves the rest of the seeded schedule untouched
        leader_kills_per_hour: if kill { 6.0 } else { 0.0 },
        ..Default::default()
    };
    p.install_chaos(&plan);
    if kill {
        // pin one kill mid-campaign regardless of the Poisson draw
        p.chaos_mut().unwrap().inject(700.0, Fault::LeaderKill { shard: None });
    }
    let _wls = common::submit_cpu_batch(&mut p, 20, 16_000, 400.0, true);
    p.run_for(3600.0, 15.0);

    let mut out = String::new();
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    for t in p.health().transitions_since(0) {
        out.push_str(&format!(
            "{:10.3} HEALTH {} {} {}\n",
            t.at,
            t.site,
            t.status.as_str(),
            t.reason
        ));
    }
    (out, p.failovers())
}

/// The HA acceptance criterion: a campaign whose leader is repeatedly
/// killed — each kill promoting the hot standby from the transferred
/// snapshot plus the shipped WAL tail — converges to a transition log
/// byte-identical to an uninterrupted run of the same seed.
#[test]
fn leader_kill_campaign_converges_to_uninterrupted_trace() {
    let seed = common::test_seed();
    let (clean, failovers_clean) = ha_trace(seed, false);
    let (killed, failovers_killed) = ha_trace(seed, true);
    assert_eq!(failovers_clean, 0);
    assert!(failovers_killed >= 1, "the pinned kill must promote the standby");
    assert!(!clean.is_empty());
    assert_eq!(
        clean, killed,
        "a failed-over control plane must converge to the uninterrupted run's \
         transition log"
    );
}

// ------------------------------------------------- split-brain fencing

/// A partitioned leader keeps the lease from renewing; the standby
/// promotes under a bumped epoch, and when the deposed leader resurfaces
/// every one of its stale-epoch writes is rejected at the store/Kueue
/// guards: the store does not move, nothing reaches the WAL, and each
/// rejection is counted.
#[test]
fn split_brain_deposed_leader_writes_are_all_fenced() {
    let mut p = replicated_platform(30.0, 0, 300.0);
    p.install_chaos(&quiet_plan(1));
    let wls = common::submit_cpu_batch(&mut p, 4, 8_000, 300.0, false);
    p.run_for(120.0, 15.0);
    assert_eq!(p.current_epoch(), 1);
    p.chaos_mut().unwrap().inject(130.0, Fault::LeaderIsolate);
    p.run_for(120.0, 15.0);
    assert_eq!(p.failovers(), 1, "lease expiry under isolation must promote");
    assert_eq!(p.current_epoch(), 2);

    // the deposed leader comes back from the partition and keeps writing
    p.resurrect_deposed_leader();
    let rv = p.cluster().resource_version();
    let logged = p.wal_handle().unwrap().borrow().appended();
    let fenced_before = p.fenced_writes();
    for j in 0..5 {
        let r = p.submit_batch(
            &format!("user{:03}", 60 + j),
            "project05",
            ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
            120.0,
            PriorityClass::Batch,
            false,
        );
        assert!(r.is_err(), "stale-epoch write {j} must be rejected");
    }
    assert_eq!(p.cluster().resource_version(), rv, "the store must not move");
    assert_eq!(
        p.wal_handle().unwrap().borrow().appended(),
        logged,
        "fenced writes must never reach the log"
    );
    assert_eq!(p.fenced_writes(), fenced_before + 5, "every rejection counted");

    // fence restored: the legitimate epoch writes again and the campaign
    // drains to completion
    p.refence_writer();
    let late = p
        .submit_batch(
            "user066",
            "project05",
            ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
            120.0,
            PriorityClass::Batch,
            false,
        )
        .unwrap();
    p.run_for(hours(1.0), 15.0);
    for w in wls.iter().chain(std::iter::once(&late)) {
        assert_eq!(p.workload_state(w), Some(WorkloadState::Finished), "{w}");
    }
    p.cluster().check_free_index();
}

// --------------------------------------------------- seeded kill sweep

/// Kill the leader at a seed-derived point in each of 8 runs (holdback
/// zero): the standby promotes, no acknowledged mutation is lost (every
/// shipped frame is replayed, nothing was left unshipped, no tail was
/// truncated), every workload still finishes, completion accounting
/// balances, quota drains, and the rebuilt free-capacity index checks.
#[test]
fn seeded_leader_kill_sweep_loses_no_acknowledged_mutation() {
    let base = common::test_seed();
    for i in 0..8u64 {
        let mut p = replicated_platform(10.0, 0, 120.0);
        p.install_chaos(&quiet_plan(base.wrapping_add(i)));
        let n = 6usize;
        let wls: Vec<String> = (0..n)
            .map(|j| {
                p.submit_batch(
                    &format!("user{:03}", (i as usize * 7 + j) % 78),
                    "project04",
                    ResourceVec::cpu_millis(8000).with(MEMORY, 8 << 30),
                    300.0,
                    PriorityClass::Batch,
                    j % 2 == 0,
                )
                .unwrap()
            })
            .collect();
        let kill_at =
            40.0 + (base.wrapping_mul(2_654_435_761).wrapping_add(i * 97) % 900) as f64;
        p.chaos_mut().unwrap().inject(kill_at, Fault::LeaderKill { shard: None });
        p.run_for(hours(2.0), 15.0);
        assert_eq!(p.failovers(), 1, "run {i}, kill at {kill_at}");
        let m = p.metrics();
        assert_eq!(m.unshipped_frames_lost, 0, "run {i}: acknowledged mutations lost");
        assert_eq!(
            m.promotion_frames_shipped, m.promotion_frames_replayed,
            "run {i}: shipped-frame coverage must equal replayed mutations"
        );
        assert_eq!(m.wal_replay_truncated, 0, "run {i}: no tail may be discarded");
        for w in &wls {
            assert_eq!(
                p.workload_state(w),
                Some(WorkloadState::Finished),
                "run {i}, kill at {kill_at}: workload {w} lost"
            );
        }
        let m = p.metrics();
        assert_eq!(
            m.local_completions + m.remote_completions + m.terminal_failures,
            n as u64,
            "run {i}, kill at {kill_at}: {m:?}"
        );
        let (used, _) = p.quota_utilization();
        assert!(used.is_empty(), "run {i}, kill at {kill_at}: leaked quota {used}");
        p.cluster().check_free_index();
    }
}

// ----------------------------------------------- availability window

/// With the lease longer than the tick period the platform rides out a
/// genuine dead window: ticks are skipped while the lease runs down, the
/// shipping channel keeps draining the durable log the world still
/// appends to, and the standby promotes within one lease interval of the
/// kill. Nothing is lost.
#[test]
fn promotion_lands_within_one_lease_interval() {
    let mut p = replicated_platform(60.0, 0, 300.0);
    p.install_chaos(&quiet_plan(3));
    let wls = common::submit_cpu_batch(&mut p, 4, 8_000, 600.0, false);
    p.run_for(300.0, 15.0);
    p.chaos_mut().unwrap().inject(310.0, Fault::LeaderKill { shard: None });
    assert!(p.leader_alive());
    // one lease interval plus one tick past the kill: promoted by then
    p.run_for(90.0, 15.0);
    assert_eq!(p.failovers(), 1, "standby must promote within one lease interval");
    assert!(p.leader_alive(), "the promoted standby is the new leader");
    let dead = p.metrics().leader_dead_ticks;
    assert!(
        (1..=4).contains(&dead),
        "the dead window spans the lease remainder, got {dead} ticks"
    );
    assert_eq!(p.metrics().unshipped_frames_lost, 0);
    p.run_for(hours(2.0), 15.0);
    for w in &wls {
        assert_eq!(p.workload_state(w), Some(WorkloadState::Finished), "{w}");
    }
    let (used, _) = p.quota_utilization();
    assert!(used.is_empty(), "leaked quota {used}");
    p.cluster().check_free_index();
}

// --------------------------------------------- damaged replica state

/// A damaged shipped tail does not block failover: promotion replays the
/// intact prefix, counts the truncation, and surfaces a typed
/// `WalIntact=false` condition on the restore report.
#[test]
fn damaged_shipped_tail_truncates_and_surfaces_condition() {
    // snapshot cadence beyond the horizon: the whole run stays in the
    // replica's shipped log, so the tail is there to damage
    let mut p = replicated_platform(10.0, 0, 10_000.0);
    p.install_chaos(&quiet_plan(4));
    let wls = common::submit_cpu_batch(&mut p, 4, 8_000, 600.0, false);
    p.run_for(300.0, 15.0);
    let len = p.replica_log_len();
    assert!(len > 40, "the run must have shipped something");
    // flip a byte inside the newest shipped frame, as standby-side media
    // corruption would
    p.corrupt_replica_log(len - 20);
    p.chaos_mut().unwrap().inject(310.0, Fault::LeaderKill { shard: None });
    p.run_for(30.0, 15.0);
    assert_eq!(p.failovers(), 1, "a damaged tail must not block failover");
    let m = p.metrics();
    assert_eq!(m.wal_replay_truncated, 1);
    assert!(
        m.promotion_frames_replayed < m.promotion_frames_shipped,
        "the damaged frame (and anything after it) must be dropped"
    );
    let r = p.last_restore().expect("promotion must record a restore report");
    assert_eq!(r.kind, "promotion");
    assert!(r.truncation.is_some());
    let c = r.condition();
    assert_eq!(c.ctype, "WalIntact");
    assert!(!c.status, "the condition must report the discarded tail");
    // the intact prefix still carries the campaign to completion
    p.run_for(hours(2.0), 15.0);
    for w in &wls {
        assert_eq!(p.workload_state(w), Some(WorkloadState::Finished), "{w}");
    }
    p.cluster().check_free_index();
}

/// A transferred snapshot that fails decode aborts the promotion cleanly:
/// no live state is touched, the epoch is not burned, the failure is
/// counted, and the attempt retries (and keeps failing) instead of
/// promoting garbage.
#[test]
fn malformed_transferred_snapshot_aborts_promotion_cleanly() {
    let mut p = replicated_platform(10.0, 0, 300.0);
    p.install_chaos(&quiet_plan(5));
    let _wls = common::submit_cpu_batch(&mut p, 2, 8_000, 300.0, false);
    p.run_for(120.0, 15.0);
    p.truncate_replica_snapshot(16);
    p.chaos_mut().unwrap().inject(130.0, Fault::LeaderKill { shard: None });
    p.run_for(60.0, 15.0);
    assert_eq!(p.failovers(), 0, "promotion must not proceed from a snapshot that fails decode");
    assert!(p.metrics().failed_promotions >= 1, "each clean abort is counted");
    assert!(!p.leader_alive(), "the dead window persists until a promotion succeeds");
    assert_eq!(p.current_epoch(), 1, "a failed promotion must not burn an epoch");
    p.cluster().check_free_index();
}

// ------------------------------------------------- shipping holdback

/// With a nonzero shipping holdback the newest frames are by construction
/// unshipped when the leader dies; the promotion measures exactly that
/// bounded loss and the platform stays invariant-clean.
#[test]
fn ship_holdback_bounds_post_kill_loss() {
    let mut p = replicated_platform(10.0, 4, 300.0);
    p.install_chaos(&quiet_plan(6));
    let _wls = common::submit_cpu_batch(&mut p, 6, 8_000, 300.0, false);
    p.run_for(200.0, 15.0);
    p.chaos_mut().unwrap().inject(205.0, Fault::LeaderKill { shard: None });
    p.run_for(60.0, 15.0);
    assert_eq!(p.failovers(), 1);
    let lost = p.metrics().unshipped_frames_lost;
    assert!(
        (1..=4).contains(&lost),
        "loss must be bounded by the 4-frame holdback, got {lost}"
    );
    p.cluster().check_free_index();
}
