//! Inference-serving integration suite: golden-trace determinism with the
//! open-loop traffic generator enabled, the scale-to-zero → cold-start →
//! burst-recovery lifecycle, randomized replica-bound / request-accounting
//! invariant sweeps, API verb round-trips for the `InferenceServer` kind,
//! and serving under chaos (site outages + GPU degradation) with the
//! no-silent-drops contract.

mod common;

use aiinfn::api::{ApiError, ApiObject, Condition, InferenceServerResource, ResourceKind, Selector};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::monitoring::tsdb::SeriesKey;
use aiinfn::platform::Platform;
use aiinfn::serve::ServingSpec;
use aiinfn::sim::chaos::ChaosPlan;
use aiinfn::sim::clock::Time;
use aiinfn::sim::traffic::{Burst, TrafficEngine, TrafficPattern, TrafficPlan};

/// A CPU-only serving spec: replicas always schedulable, so the latency
/// and autoscale assertions are isolated from GPU partition dynamics.
fn cpu_spec(name: &str, min_replicas: u32, max_replicas: u32) -> ServingSpec {
    ServingSpec {
        name: name.to_string(),
        user: "user001".to_string(),
        project: "project01".to_string(),
        model: "deepmet".to_string(),
        requests: ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
        min_replicas,
        max_replicas,
        latency_slo: 0.5,
        max_batch: 8,
        batch_window: 0.02,
        service_time: 0.08, // mu = 100 req/s per replica
        queue_depth: 256,
        queue: "serving".to_string(),
    }
}

/// A MIG-slice-sized spec (the paper's serving shape): exercises the
/// demand-driven repartition path on the shared A100s.
fn mig_spec(name: &str, min_replicas: u32, max_replicas: u32) -> ServingSpec {
    ServingSpec {
        requests: ResourceVec::cpu_millis(2000)
            .with(MEMORY, 4 << 30)
            .with("nvidia.com/mig-1g.5gb", 1),
        ..cpu_spec(name, min_replicas, max_replicas)
    }
}

/// `total == completed + failed + queued`: every arrival is either served,
/// counted as failed (shed / lost to a replica death), or still in flight.
/// Nothing is ever silently dropped.
fn assert_accounting(p: &Platform, name: &str) {
    let s = p.serving_state(name).unwrap();
    assert_eq!(
        s.total_requests,
        s.completed_requests + s.failed_requests + s.queued(),
        "request accounting must balance for {name}"
    );
}

// ------------------------------------------------------------ golden trace

/// One serving scenario rendered as a text blob: traffic-engine log,
/// per-server serving transition log, cluster events, Kueue transitions.
fn serving_trace(seed: u64) -> String {
    let mut p = common::platform();
    let mut engine = TrafficEngine::new(seed);
    engine.add(0.0, TrafficPattern::flat("srv-a", 30.0));
    engine.add(
        0.0,
        TrafficPattern {
            bursts: vec![Burst { at: 600.0, duration: 300.0, add_rps: 80.0 }],
            ..TrafficPattern::flat("srv-b", 10.0)
        },
    );
    p.set_traffic(engine);
    p.create_inference_server(cpu_spec("srv-a", 1, 4)).unwrap();
    p.create_inference_server(cpu_spec("srv-b", 0, 3)).unwrap();
    p.run_for(1800.0, 15.0);

    let mut out = String::new();
    out.push_str(&p.traffic().unwrap().trace());
    out.push_str(&p.serving_trace());
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    out
}

/// Same seed ⇒ byte-identical trace with the serving subsystem and traffic
/// generator live; different seed ⇒ different arrivals, different trace.
#[test]
fn serving_golden_trace_same_seed_is_byte_identical() {
    let seed = common::test_seed();
    let a = serving_trace(seed);
    let b = serving_trace(seed);
    assert!(!a.is_empty());
    assert!(a.contains("SERVING"), "trace must include serving transitions");
    assert_eq!(a, b, "same traffic seed must reproduce the serving trace byte-for-byte");
    let c = serving_trace(seed.wrapping_add(1));
    assert_ne!(a, c, "different traffic seeds must produce different traces");
}

// ------------------------------------- scale-to-zero → cold start → burst

/// The full autoscale lifecycle on one server with `min_replicas = 0`:
/// a burst is served within SLO, a long idle gap scales the fleet to
/// zero, a second burst cold-starts replicas (arrivals buffer in the
/// backlog, the cold-start penalty is paid and counted), and p95
/// recovers to under the SLO while the burst is still running.
#[test]
fn scale_to_zero_cold_start_and_burst_recovery() {
    let mut p = common::platform();
    let mut engine = TrafficEngine::new(common::test_seed());
    engine.add(
        0.0,
        TrafficPattern {
            bursts: vec![
                Burst { at: 0.0, duration: 2400.0, add_rps: 40.0 },
                Burst { at: 6000.0, duration: 2400.0, add_rps: 60.0 },
            ],
            ..TrafficPattern::flat("deepmet-serve", 0.0)
        },
    );
    p.set_traffic(engine);
    p.create_inference_server(cpu_spec("deepmet-serve", 0, 4)).unwrap();

    // burst A: the fleet serves within SLO
    p.run_for(2400.0, 15.0);
    {
        let s = p.serving_state("deepmet-serve").unwrap();
        assert!(s.completed_requests > 0, "burst A must be served");
        assert!(s.ready_count() >= 1);
        assert!(
            s.last_p95 <= s.spec.latency_slo,
            "p95 {:.3}s must sit under the {:.3}s SLO at steady state",
            s.last_p95,
            s.spec.latency_slo
        );
    }
    assert_accounting(&p, "deepmet-serve");

    // idle gap: past the idle grace the autoscaler walks the fleet to zero
    p.run_for(3100.0, 15.0); // now at t = 5500
    {
        let s = p.serving_state("deepmet-serve").unwrap();
        assert_eq!(s.replicas.len(), 0, "idle server must scale to zero");
        assert_eq!(s.state_str(), "Idle");
        assert_eq!(s.queued(), 0);
    }
    let cold_starts_before = p.metrics().serving_cold_starts;

    // burst B into a cold fleet: backlog buffers, replicas cold-start,
    // the autoscaler scales out, and p95 recovers under SLO before the
    // burst ends
    p.run_for(2800.0, 15.0); // now at t = 8300, burst B ends at 8400
    {
        let s = p.serving_state("deepmet-serve").unwrap();
        assert!(s.ready_count() >= 1, "burst B must cold-start replicas");
        assert!(
            p.metrics().serving_cold_starts > cold_starts_before,
            "recovering from zero must pay (and count) a cold start"
        );
        assert!(
            s.last_p95 <= s.spec.latency_slo,
            "p95 {:.3}s must recover under the {:.3}s SLO during burst B",
            s.last_p95,
            s.spec.latency_slo
        );
        assert!(s.replicas.len() as u32 <= s.spec.max_replicas);
    }
    assert_accounting(&p, "deepmet-serve");
    assert!(p.metrics().serving_scale_events > 0, "the autoscaler must have acted");

    // the autoscale signals are dashboard-visible: the p95 series exists
    let key = SeriesKey::new("serving_p95_seconds", &[("server", "deepmet-serve")]);
    assert!(
        p.tsdb.max_over(&key, 6000.0, 8300.0).is_some(),
        "serving p95 must be ingested into the TSDB"
    );
}

// ------------------------------------------------- randomized invariants

/// Across randomized traffic plans (MIG-slice-sized replicas, diurnal +
/// Poisson bursts): the fleet never leaves `[min, max]` while traffic is
/// nonzero, and request accounting balances at every sampled boundary.
#[test]
fn replica_bounds_and_accounting_hold_under_random_traffic() {
    let base = common::test_seed();
    for i in 0..8u64 {
        let seed = base.wrapping_mul(100).wrapping_add(i);
        let mut p = common::platform();
        let plan = TrafficPlan {
            seed,
            horizon: 7200.0,
            bursts_per_hour: 2.0,
            ..Default::default()
        };
        let baseline = TrafficPattern {
            diurnal_amplitude: 0.5,
            ..TrafficPattern::flat("mig-serve", 20.0)
        };
        let engine = plan.generate(vec![baseline]);
        p.set_traffic(engine);
        let spec = mig_spec("mig-serve", 1, 3);
        let (min, max) = (spec.min_replicas, spec.max_replicas);
        p.create_inference_server(spec).unwrap();

        let mut t: Time = 0.0;
        while t < 7200.0 {
            p.run_for(120.0, 15.0);
            t += 120.0;
            let s = p.serving_state("mig-serve").unwrap();
            let n = s.replicas.len() as u32;
            assert!(
                (min..=max).contains(&n),
                "seed {seed} t={t}: fleet size {n} outside [{min}, {max}]"
            );
            assert_accounting(&p, "mig-serve");
        }
        let s = p.serving_state("mig-serve").unwrap();
        assert!(s.total_requests > 0, "seed {seed}: the generator must produce arrivals");
    }
}

/// MIG-slice-sized replicas actually reach Ready on the shared A100s —
/// queued serving demand drives the demand-driven repartition path and the
/// slices materialize.
#[test]
fn mig_replicas_schedule_through_the_repartition_path() {
    let mut p = common::platform();
    let mut engine = TrafficEngine::new(common::test_seed());
    engine.add(0.0, TrafficPattern::flat("mig-serve", 30.0));
    p.set_traffic(engine);
    p.create_inference_server(mig_spec("mig-serve", 1, 3)).unwrap();
    p.run_for(1200.0, 15.0);
    let s = p.serving_state("mig-serve").unwrap();
    assert!(
        s.ready_count() >= 1,
        "MIG-sized serving replicas must become Ready (repartition path): state={} log:\n{}",
        s.state_str(),
        s.trace()
    );
    assert!(s.completed_requests > 0);
    assert_accounting(&p, "mig-serve");
}

// ----------------------------------------------------------- API verbs

#[test]
fn inference_server_api_verbs_roundtrip() {
    let mut api = common::api();
    let token = api.login("user010").unwrap();

    // create (client-named) — admission defaults the batching knobs
    let req = InferenceServerResource::request(
        "cms-tracker",
        "user010",
        "project03",
        "deepmet",
        ResourceVec::cpu_millis(2000).with(MEMORY, 4 << 30),
        0,
        3,
        0.5,
    );
    let created = api.create(&token, &ApiObject::InferenceServer(req.clone())).unwrap();
    let view = created.as_inference_server().unwrap();
    assert_eq!(view.queue, "serving", "admission must default the serving queue");
    assert!(view.max_batch >= 1 && view.service_time > 0.0, "knobs must be defaulted");

    // duplicate create conflicts
    assert!(matches!(
        api.create(&token, &ApiObject::InferenceServer(req.clone())),
        Err(ApiError::Conflict(_))
    ));

    // another user cannot create in user010's name
    let other = api.login("user011").unwrap();
    assert!(matches!(
        api.create(&other, &ApiObject::InferenceServer(req)),
        Err(ApiError::Forbidden(_))
    ));

    // get + label-selector list
    api.run_for(120.0, 15.0);
    let got = api.get(&token, ResourceKind::InferenceServer, "cms-tracker").unwrap();
    let got = got.as_inference_server().unwrap();
    assert!(got.replicas >= 1, "create provisions at least one replica");
    let listed = api
        .list(&token, ResourceKind::InferenceServer, &Selector::labels("app=inference").unwrap())
        .unwrap();
    assert_eq!(listed.len(), 1);

    // update: scaling knobs move, identity is immutable
    let mut upd = got.clone();
    upd.max_replicas = 2;
    upd.latency_slo = 0.8;
    let updated = api.update(&token, &ApiObject::InferenceServer(upd)).unwrap();
    let updated = updated.as_inference_server().unwrap();
    assert_eq!(updated.max_replicas, 2);
    assert!((updated.latency_slo - 0.8).abs() < 1e-9);
    let mut bad = updated.clone();
    bad.model = "other-model".to_string();
    assert!(matches!(
        api.update(&token, &ApiObject::InferenceServer(bad)),
        Err(ApiError::Invalid(_))
    ));

    // status subresource: conditions only
    let mut st = updated.clone();
    st.conditions = vec![Condition::new("Degraded", true, "ManualFlag", "ops note", 0.0)];
    let after = api.update_status(&token, &ApiObject::InferenceServer(st)).unwrap();
    assert_eq!(after.as_inference_server().unwrap().conditions.len(), 1);

    // delete: only the owner may; the fleet tears down on the next tick
    assert!(matches!(
        api.delete(&other, ResourceKind::InferenceServer, "cms-tracker"),
        Err(ApiError::Forbidden(_))
    ));
    api.delete(&token, ResourceKind::InferenceServer, "cms-tracker").unwrap();
    assert!(matches!(
        api.get(&token, ResourceKind::InferenceServer, "cms-tracker"),
        Err(ApiError::NotFound(_))
    ));
    api.run_for(60.0, 15.0);
    assert!(api.platform().serving_state("cms-tracker").is_none());
    assert!(api.platform().inference_server_names().is_empty());
}

// ------------------------------------------------------- serving + chaos

/// Serving through randomized chaos (site outages, node flaps, GPU
/// degradation): replicas die and reincarnate, but no request is ever
/// silently dropped — every arrival is completed, counted failed, or
/// still queued — and the fleet stays within its bounds.
#[test]
fn serving_under_chaos_counts_every_request() {
    let base = common::test_seed();
    for i in 0..6u64 {
        let seed = base.wrapping_mul(77).wrapping_add(i);
        let mut p = common::platform();
        let plan = ChaosPlan {
            seed,
            horizon: 5400.0,
            site_outages_per_hour: 1.0,
            node_flaps_per_hour: 1.0,
            node_down_duration: (60.0, 240.0),
            gpu_degrades_per_hour: 1.0,
            gpu_degrade_duration: (120.0, 600.0),
            ..Default::default()
        };
        p.install_chaos(&plan);
        let traffic = TrafficPlan {
            seed: seed.wrapping_add(1),
            horizon: 5400.0,
            bursts_per_hour: 1.0,
            ..Default::default()
        };
        p.set_traffic(traffic.generate(vec![TrafficPattern::flat("chaos-serve", 25.0)]));
        let spec = cpu_spec("chaos-serve", 1, 4);
        let (min, max) = (spec.min_replicas, spec.max_replicas);
        p.create_inference_server(spec).unwrap();
        p.run_for(5400.0, 15.0);

        let s = p.serving_state("chaos-serve").unwrap();
        assert!(s.total_requests > 0, "seed {seed}: arrivals expected");
        assert!(s.completed_requests > 0, "seed {seed}: the fleet must serve through chaos");
        assert_accounting(&p, "chaos-serve");
        let n = s.replicas.len() as u32;
        assert!(
            (min..=max).contains(&n),
            "seed {seed}: fleet size {n} outside [{min}, {max}] after chaos"
        );
        // the facade-level counters agree with the per-server ledger
        let m = p.metrics();
        assert_eq!(m.serving_requests, s.total_requests, "seed {seed}");
        assert_eq!(m.serving_completions, s.completed_requests, "seed {seed}");
    }
}
