//! Declarative write-path integration tests: optimistic concurrency,
//! admission, apply/patch, the status subresource, finalizers, and the
//! ownerReferences garbage-collection cascade.

mod common;

use aiinfn::api::{
    ApiError, ApiObject, BatchJobResource, Condition, EventType, ResourceKind, Selector,
    SessionResource,
};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::queue::kueue::PriorityClass;
use aiinfn::util::json::Json;

fn job_request(user: &str, project: &str, duration: f64) -> ApiObject {
    ApiObject::BatchJob(BatchJobResource::request(
        user,
        project,
        ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
        duration,
        PriorityClass::Batch,
        false,
    ))
}

/// A write carrying a stale `metadata.resourceVersion` fails with
/// `Conflict`; re-reading and echoing the fresh version succeeds, and the
/// mutable spec fields (offloadable, restartPolicy) actually move.
#[test]
fn stale_resource_version_update_conflicts() {
    let mut api = common::api();
    let token = api.login("user020").unwrap();
    let created = api.create(&token, &job_request("user020", "project04", 300.0)).unwrap();
    let name = created.name().to_string();
    let stale_rv = created.metadata().resource_version;
    assert!(stale_rv > 0);

    // admission + scheduling bump the object's version…
    api.run_for(60.0, 10.0);
    let mut with_stale = created.as_batch_job().unwrap().clone();
    with_stale.offloadable = true;
    assert!(
        matches!(
            api.update(&token, &ApiObject::BatchJob(with_stale)),
            Err(ApiError::Conflict(_))
        ),
        "stale resourceVersion must conflict"
    );

    // …so a fresh read-modify-write is required
    let fresh = api.get(&token, ResourceKind::BatchJob, &name).unwrap();
    let mut job = fresh.as_batch_job().unwrap().clone();
    job.offloadable = true;
    job.restart_policy = "Never".to_string();
    let updated = api.update(&token, &ApiObject::BatchJob(job)).unwrap();
    let updated = updated.as_batch_job().unwrap();
    assert!(updated.offloadable);
    assert_eq!(updated.restart_policy, "Never");
    // …and an unconditional write (resourceVersion = 0) is allowed
    let mut job = updated.clone();
    job.metadata.resource_version = 0;
    job.offloadable = false;
    let back = api.update(&token, &ApiObject::BatchJob(job)).unwrap();
    assert!(!back.as_batch_job().unwrap().offloadable);
}

/// Admission rejections surface as typed `Invalid` errors naming the
/// admitter, both on create (validation) and update (immutable fields).
#[test]
fn admission_rejection_surfaces_as_invalid() {
    let mut api = common::api();
    let token = api.login("user021").unwrap();

    // empty resource requests: rejected by the validating admitter
    let empty = ApiObject::BatchJob(BatchJobResource::request(
        "user021",
        "project04",
        ResourceVec::new(),
        100.0,
        PriorityClass::Batch,
        false,
    ));
    match api.create(&token, &empty) {
        Err(ApiError::Invalid(msg)) => assert!(msg.contains("admission denied"), "{msg}"),
        other => panic!("expected Invalid, got {other:?}"),
    }

    // non-positive duration
    let zero = job_request("user021", "project04", 0.0);
    assert!(matches!(api.create(&token, &zero), Err(ApiError::Invalid(_))));

    // immutable-field change on update
    let created = api.create(&token, &job_request("user021", "project04", 200.0)).unwrap();
    let mut mutated = created.as_batch_job().unwrap().clone();
    mutated.duration = 999.0;
    match api.update(&token, &ApiObject::BatchJob(mutated)) {
        Err(ApiError::Invalid(msg)) => assert!(msg.contains("immutable"), "{msg}"),
        other => panic!("expected Invalid(immutable), got {other:?}"),
    }
}

/// Deleting a Workload cascades to its owned Pods through the GC
/// reconciler: the pods carry `ownerReferences` to the Workload, the
/// delete verb returns the final object, and one tick later the pods are
/// gone (with `Deleted` watch events) and the quota is released.
#[test]
fn workload_delete_cascades_to_owned_pods() {
    let mut api = common::api();
    let token = api.login("user022").unwrap();
    let created = api.create(&token, &job_request("user022", "project05", 600.0)).unwrap();
    let wl = created.name().to_string();
    api.run_for(60.0, 10.0);

    let pods = api
        .list(&token, ResourceKind::Pod, &Selector::labels("app=batch").unwrap())
        .unwrap();
    assert_eq!(pods.len(), 1, "the admitted job realizes one pod");
    let pod_name = pods[0].name().to_string();
    let owners = &pods[0].as_pod().unwrap().metadata.owner_references;
    assert!(
        owners.iter().any(|o| o.kind == ResourceKind::Workload && o.name == wl),
        "pod must reference its owning Workload: {owners:?}"
    );

    let rv0 = api.last_rv();
    let last = api.delete(&token, ResourceKind::Workload, &wl).unwrap();
    assert!(last.metadata().deletion_timestamp.is_some());
    assert!(matches!(
        api.get(&token, ResourceKind::Workload, &wl),
        Err(ApiError::NotFound(_))
    ));

    // the GC reconciler converges the cascade on the next tick
    api.tick();
    assert!(matches!(
        api.get(&token, ResourceKind::Pod, &pod_name),
        Err(ApiError::NotFound(_))
    ));
    assert!(api
        .list(&token, ResourceKind::Pod, &Selector::labels("app=batch").unwrap())
        .unwrap()
        .is_empty());
    let deleted_events: Vec<_> = api
        .watch(&token, ResourceKind::Pod, rv0)
        .unwrap()
        .into_iter()
        .filter(|e| e.name == pod_name && e.event == EventType::Deleted)
        .collect();
    assert_eq!(deleted_events.len(), 1, "one Deleted watch event for the GC'd pod");
    // quota fully released
    let (used, _) = api.platform().quota_utilization();
    assert!(used.is_empty(), "leaked quota {used}");
    assert_eq!(api.platform().metrics().terminal_failures, 0);
}

/// An object with pending finalizers enters the terminating state on
/// delete (`deletionTimestamp` set, still readable) and is only removed —
/// cascading through the GC — once a write clears the finalizers.
#[test]
fn finalizers_defer_deletion_until_cleared() {
    let mut api = common::api();
    let token = api.login("user023").unwrap();
    let created = api.create(&token, &job_request("user023", "project06", 400.0)).unwrap();
    let name = created.name().to_string();

    // attach a finalizer via strategic-merge patch
    let patch = Json::parse(r#"{"metadata":{"finalizers":["example.com/archive"]}}"#).unwrap();
    let patched = api.patch(&token, ResourceKind::BatchJob, &name, &patch).unwrap();
    assert_eq!(
        patched.metadata().finalizers,
        vec!["example.com/archive".to_string()]
    );

    // delete: terminating, not gone
    let terminating = api.delete(&token, ResourceKind::BatchJob, &name).unwrap();
    assert!(terminating.metadata().deletion_timestamp.is_some());
    let still = api.get(&token, ResourceKind::BatchJob, &name).unwrap();
    assert!(still.metadata().terminating());
    api.tick();
    assert!(
        api.get(&token, ResourceKind::BatchJob, &name).is_ok(),
        "finalizer-blocked object survives ticks"
    );

    // a malformed (non-array) finalizers value is rejected, not read as []
    // — that would silently complete the deletion
    let bad = Json::parse(r#"{"metadata":{"finalizers":"example.com/archive"}}"#).unwrap();
    assert!(matches!(
        api.patch(&token, ResourceKind::BatchJob, &name, &bad),
        Err(ApiError::Invalid(_))
    ));

    // clearing the finalizers completes the deletion
    let clear = Json::parse(r#"{"metadata":{"finalizers":[]}}"#).unwrap();
    let last = api.patch(&token, ResourceKind::BatchJob, &name, &clear).unwrap();
    assert!(last.metadata().deletion_timestamp.is_some());
    assert!(matches!(
        api.get(&token, ResourceKind::BatchJob, &name),
        Err(ApiError::NotFound(_))
    ));
    api.tick();
    let wl = api.get(&token, ResourceKind::Workload, &name).unwrap();
    assert_eq!(wl.as_workload().unwrap().state, "Finished", "GC cancelled the job");
}

/// `apply` is a create-or-update upsert, and the update leg is observable
/// on the watch stream as a `Modified` delta carrying the new spec.
#[test]
fn apply_upsert_roundtrips_through_watch() {
    let mut api = common::api();
    let token = api.login("user024").unwrap();
    let rv0 = api.last_rv();

    // first apply: create
    let created = api.apply(&token, &job_request("user024", "project07", 500.0)).unwrap();
    let name = created.name().to_string();
    assert!(!created.as_batch_job().unwrap().offloadable);

    // second apply: update (carrying the fresh resourceVersion)
    let mut desired = created.as_batch_job().unwrap().clone();
    desired.offloadable = true;
    let applied = api.apply(&token, &ApiObject::BatchJob(desired)).unwrap();
    assert!(applied.as_batch_job().unwrap().offloadable);

    let events = api.watch(&token, ResourceKind::BatchJob, rv0).unwrap();
    let mine: Vec<_> = events.into_iter().filter(|e| e.name == name).collect();
    assert_eq!(mine.first().map(|e| e.event), Some(EventType::Added), "{mine:?}");
    let modified_offloadable = mine.iter().any(|e| {
        e.event == EventType::Modified
            && e.object
                .as_ref()
                .and_then(|o| o.at(&["spec", "offloadable"]))
                .and_then(Json::as_bool)
                == Some(true)
    });
    assert!(modified_offloadable, "apply must surface as a Modified delta: {mine:?}");
}

/// The status subresource writes conditions without touching the spec,
/// and spec updates preserve status-written conditions — the two write
/// paths cannot clobber each other. Stale versions conflict here too.
#[test]
fn status_subresource_is_isolated_from_spec() {
    let mut api = common::api();
    let token = api.login("user025").unwrap();
    let created = api.create(&token, &job_request("user025", "project08", 300.0)).unwrap();
    let name = created.name().to_string();

    // status write: a condition, plus a sneaky spec change that must NOT land
    let mut status_obj = created.as_batch_job().unwrap().clone();
    status_obj.offloadable = true; // ignored by the status subresource
    status_obj.conditions =
        vec![Condition::new("Archived", true, "Test", "set via status subresource", 1.0)];
    let after = api.update_status(&token, &ApiObject::BatchJob(status_obj)).unwrap();
    let after = after.as_batch_job().unwrap();
    assert!(!after.offloadable, "status write must not touch the spec");
    assert!(after.conditions.iter().any(|c| c.ctype == "Archived"));

    // spec write: preserves the status-written condition
    let mut spec_obj = after.clone();
    spec_obj.offloadable = true;
    spec_obj.conditions = Vec::new(); // ignored by the spec path
    let after2 = api.update(&token, &ApiObject::BatchJob(spec_obj)).unwrap();
    let after2 = after2.as_batch_job().unwrap();
    assert!(after2.offloadable);
    assert!(
        after2.conditions.iter().any(|c| c.ctype == "Archived"),
        "spec write must not clobber status conditions"
    );

    // stale status write conflicts
    let mut stale = created.as_batch_job().unwrap().clone();
    stale.conditions = vec![Condition::new("Stale", true, "Old", "stale rv", 2.0)];
    assert!(matches!(
        api.update_status(&token, &ApiObject::BatchJob(stale)),
        Err(ApiError::Conflict(_))
    ));
}

/// Deleting a Session cascades to its pod (removed from the store by the
/// GC reconciler) and its volume-claim-like attachments (the rclone
/// bucket mount dies with the session).
#[test]
fn session_delete_cascades_to_pod_and_claims() {
    let mut api = common::api();
    let token = api.login("user026").unwrap();
    let created = api
        .create(
            &token,
            &ApiObject::Session(SessionResource::request("user026", "cpu-small")),
        )
        .unwrap();
    let sid = created.name().to_string();
    api.run_for(60.0, 10.0);
    let session = api.get(&token, ResourceKind::Session, &sid).unwrap();
    let pod_name = session.as_session().unwrap().pod_name.clone();
    let pod = api.get(&token, ResourceKind::Pod, &pod_name).unwrap();
    assert!(
        pod.metadata()
            .owner_references
            .iter()
            .any(|o| o.kind == ResourceKind::Session && o.name == sid),
        "session pod must reference its owning Session"
    );

    let last = api.delete(&token, ResourceKind::Session, &sid).unwrap();
    assert!(last.metadata().deletion_timestamp.is_some());
    api.tick();
    assert!(api.platform().session(&sid).is_none(), "session torn down");
    assert!(
        matches!(api.get(&token, ResourceKind::Pod, &pod_name), Err(ApiError::NotFound(_))),
        "session pod garbage-collected"
    );
    // interactive quota released with the workload
    let (used, _) = api.platform().quota_utilization();
    assert!(used.is_empty(), "leaked quota {used}");
}
