//! Control-plane integration tests: typed verbs, watch streams, and the
//! resource projections (split out of the former monolithic
//! `integration.rs`).

mod common;

use aiinfn::api::{
    ApiObject, BatchJobResource, EventType, ResourceKind, Selector, SessionResource,
};
use aiinfn::cluster::resources::{ResourceVec, MEMORY};
use aiinfn::util::json::Json;

/// The acceptance path for the API redesign: a session is created through
/// the typed API and its pod's `Added → Modified(Running)` lifecycle is
/// observed purely from the watch stream — no store polling.
#[test]
fn watch_observes_session_pod_lifecycle_without_polling() {
    let mut api = common::api();
    let token = api.login("user011").unwrap();
    let rv0 = api.last_rv();
    let created = api
        .create(
            &token,
            &ApiObject::Session(SessionResource::request("user011", "tensorflow-mig-1g")),
        )
        .unwrap();
    let pod_name = created.as_session().unwrap().pod_name.clone();
    api.run_for(120.0, 10.0);

    let events: Vec<_> = api
        .watch(&token, ResourceKind::Pod, rv0)
        .unwrap()
        .into_iter()
        .filter(|e| e.name == pod_name)
        .collect();
    assert!(events.len() >= 2, "expected Added + Modified events: {events:?}");
    // resourceVersions strictly increase along the stream
    for w in events.windows(2) {
        assert!(w[1].resource_version > w[0].resource_version);
    }
    let phases: Vec<(EventType, String)> = events
        .iter()
        .map(|e| {
            let phase = e
                .object
                .as_ref()
                .and_then(|o| o.at(&["status", "phase"]))
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string();
            (e.event, phase)
        })
        .collect();
    assert_eq!(phases[0], (EventType::Added, "Pending".to_string()), "{phases:?}");
    assert!(
        phases.iter().any(|(t, ph)| *t == EventType::Modified && ph == "Running"),
        "must observe the Running transition: {phases:?}"
    );
    // the Session resource agrees with the stream
    let s = api.get(&token, ResourceKind::Session, created.name()).unwrap();
    assert_eq!(s.as_session().unwrap().phase, "Running");
}

/// End-to-end batch flow through the verbs, with workload deltas observed
/// from the watch stream.
#[test]
fn api_batch_flow_with_workload_watch() {
    let mut api = common::api();
    let token = api.login("user030").unwrap();
    let rv0 = api.last_rv();
    let wl = api
        .create(
            &token,
            &ApiObject::BatchJob(BatchJobResource::request(
                "user030",
                "project10",
                ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
                120.0,
                aiinfn::queue::kueue::PriorityClass::Batch,
                false,
            )),
        )
        .unwrap()
        .name()
        .to_string();
    api.run_for(600.0, 10.0);
    let states: Vec<String> = api
        .watch(&token, ResourceKind::Workload, rv0)
        .unwrap()
        .into_iter()
        .filter(|e| e.name == wl)
        .filter_map(|e| {
            e.object
                .as_ref()
                .and_then(|o| o.at(&["status", "state"]))
                .and_then(Json::as_str)
                .map(String::from)
        })
        .collect();
    assert_eq!(states.first().map(String::as_str), Some("Queued"), "{states:?}");
    assert!(states.iter().any(|s| s == "Admitted"), "{states:?}");
    assert_eq!(states.last().map(String::as_str), Some("Finished"), "{states:?}");
    // the pod is findable by label selector and succeeded
    let pods = api
        .list(&token, ResourceKind::Pod, &Selector::labels("app=batch").unwrap())
        .unwrap();
    assert_eq!(pods.len(), 1);
    assert_eq!(pods[0].as_pod().unwrap().phase, "Succeeded");
    // the pod view carries typed conditions
    let conds = &pods[0].as_pod().unwrap().conditions;
    assert!(conds.iter().any(|c| c.ctype == "PodScheduled" && c.status), "{conds:?}");
    // the BatchJob status reports its restart policy and zero retries
    let job = api.get(&token, ResourceKind::BatchJob, &wl).unwrap();
    let job = job.as_batch_job().unwrap();
    assert_eq!(job.retries, 0);
    assert!(job.restart_policy.starts_with("OnFailure"), "{}", job.restart_policy);
}

/// Site resources expose circuit-breaker health and a `Healthy` condition.
#[test]
fn site_resources_report_health_conditions() {
    let mut api = common::api();
    let token = api.login("user001").unwrap();
    let sites = api.list(&token, ResourceKind::Site, &Selector::all()).unwrap();
    assert_eq!(sites.len(), 4);
    for s in &sites {
        let site = s.as_site().unwrap();
        assert_eq!(site.health, "Healthy", "{}", site.site);
        let cond = site
            .conditions
            .iter()
            .find(|c| c.ctype == "Healthy")
            .unwrap_or_else(|| panic!("no Healthy condition on {}", site.site));
        assert!(cond.status, "{}", site.site);
    }
    // health is also reachable as a field selector
    let healthy = api
        .list(&token, ResourceKind::Site, &Selector::fields("status.health=Healthy").unwrap())
        .unwrap();
    assert_eq!(healthy.len(), 4);
}
