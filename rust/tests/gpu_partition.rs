//! GPU partition-loop suite: the demand-driven MIG repartition reconciler
//! (cold whole-GPU cluster → 7 users per A100 with no admin), the
//! repartition-while-bound guard, usage-ledger accounting across the GC
//! cascade, A30 vs A100 slice-hour parity, fair-share plumbing, and the
//! chaos-sweep invariant that node extended resources always equal the sum
//! of the device layouts.

mod common;

use aiinfn::api::{ApiObject, BatchJobResource, ResourceKind, Selector};
use aiinfn::cluster::node::Node;
use aiinfn::cluster::pod::{Payload, PodPhase, PodSpec};
use aiinfn::cluster::resources::{ResourceVec, GPU, MEMORY};
use aiinfn::cluster::store::ClusterStore;
use aiinfn::gpu::{GpuDevice, GpuModel, MigLayout};
use aiinfn::monitoring::account;
use aiinfn::platform::PlatformConfig;
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::sim::chaos::ChaosPlan;

/// One server, one cold (whole) A100, federation off, fast cooldown.
const COLD_A100: &str = r#"{
  "name": "cold-a100",
  "servers": [
    {"name": "gpu-a", "year": 2023, "cpu_cores": 64, "memory_gb": 512, "nvme_tb": 4,
     "gpus": ["A100"]}
  ],
  "federation": {"enabled": false},
  "gpu": {"repartition_cooldown": 60}
}"#;

/// Acceptance: starting from a whole (unpartitioned) A100, queued
/// single-slice demand alone drives the reconciler to the 7×1g.5gb layout
/// and seven users run concurrently — the paper's sharing claim end to
/// end, with zero admin input.
#[test]
fn reconciler_unlocks_seven_users_per_a100_from_cold() {
    let cfg = PlatformConfig::parse(COLD_A100).unwrap();
    let mut api = aiinfn::api::ApiServer::bootstrap(cfg).unwrap();
    let token = api.login("user001").unwrap();
    let rv0 = api.last_rv();

    // cold: the device advertises one whole GPU
    let cold = api.list(&token, ResourceKind::GpuDevice, &Selector::all()).unwrap();
    assert_eq!(cold.len(), 1);
    assert!(cold[0].as_gpu_device().unwrap().instances.is_empty(), "MIG off at boot");

    for i in 0..7 {
        let user = format!("user{:03}", i + 1);
        let t = api.login(&user).unwrap();
        api.create(
            &t,
            &ApiObject::BatchJob(BatchJobResource::request(
                &user,
                "project01",
                ResourceVec::cpu_millis(2000)
                    .with(MEMORY, 8 << 30)
                    .with("nvidia.com/mig-1g.5gb", 1),
                3600.0,
                PriorityClass::Batch,
                false,
            )),
        )
        .unwrap();
    }
    api.run_for(300.0, 10.0);

    // the reconciler repartitioned the device to max sharing…
    let hot = api.list(&token, ResourceKind::GpuDevice, &Selector::all()).unwrap();
    let dev = hot[0].as_gpu_device().unwrap();
    assert_eq!(dev.max_users, 7, "{dev:?}");
    assert!(dev.instances.iter().all(|i| i == "1g.5gb"));
    assert_eq!(api.platform().metrics().repartitions, 1);
    // …the swap is visible on the GpuDevice watch stream…
    let modified = api
        .watch(&token, ResourceKind::GpuDevice, rv0)
        .unwrap()
        .iter()
        .filter(|e| e.event == aiinfn::api::EventType::Modified)
        .count();
    assert!(modified >= 1, "repartition must emit a GpuDevice Modified event");
    // …and all seven users run concurrently on the one physical GPU
    let running = {
        let st = api.platform().cluster();
        st.pods()
            .filter(|p| {
                p.status.phase == PodPhase::Running
                    && p.spec.requests.get("nvidia.com/mig-1g.5gb") > 0
            })
            .count()
    };
    assert_eq!(running, 7, "seven simultaneous single-slice users per A100");
    // label-indexed list by hosting node finds it too
    let by_node = api
        .list(&token, ResourceKind::GpuDevice, &Selector::labels("aiinfn/node=gpu-a").unwrap())
        .unwrap();
    assert_eq!(by_node.len(), 1);
}

/// The guard: a layout swap that would remove capacity still bound by live
/// pods is refused; the same swap succeeds once the slices are free.
#[test]
fn repartition_while_busy_is_rejected() {
    let mut s = ClusterStore::new();
    let dev = GpuDevice::partitioned(
        "g0",
        GpuModel::A100_40GB,
        MigLayout::max_sharing(GpuModel::A100_40GB).unwrap(),
    )
    .unwrap();
    s.add_node(Node::physical("n1", 32, 128 << 30, 1 << 40, vec![dev]), 0.0);
    s.create_pod(
        PodSpec::new(
            "user-pod",
            ResourceVec::cpu_millis(500).with("nvidia.com/mig-1g.5gb", 1),
            Payload::Sleep { duration: 50.0 },
        ),
        0.0,
    );
    s.bind("user-pod", "n1", 0.0).unwrap();
    let whole = MigLayout::new(GpuModel::A100_40GB, vec![]).unwrap();
    let err = s.repartition_gpu("n1", "g0", whole.clone(), 1.0).unwrap_err();
    assert!(err.to_string().contains("still bound"), "{err}");
    assert_eq!(
        s.node("n1").unwrap().allocatable.get("nvidia.com/mig-1g.5gb"),
        7,
        "refused swap must leave the advertisement untouched"
    );
    // once the slice is released, the identical swap goes through
    s.finish_pod("user-pod", PodPhase::Succeeded, 2.0, "done").unwrap();
    s.repartition_gpu("n1", "g0", whole, 3.0).unwrap();
    assert_eq!(s.node("n1").unwrap().allocatable.get(GPU), 1);
    s.check_free_index();
}

/// A30 slice-hours divide by 4, A100 slice-hours by 7 — the hardcoded-7
/// denominator under-billed A30 usage by ~43%.
#[test]
fn a30_vs_a100_accounting_parity() {
    let mut s = ClusterStore::new();
    let a100 = GpuDevice::partitioned(
        "a100-0",
        GpuModel::A100_40GB,
        MigLayout::max_sharing(GpuModel::A100_40GB).unwrap(),
    )
    .unwrap();
    let a30 = GpuDevice::partitioned(
        "a30-0",
        GpuModel::A30,
        MigLayout::max_sharing(GpuModel::A30).unwrap(),
    )
    .unwrap();
    s.add_node(Node::physical("n1", 64, 256 << 30, 1 << 40, vec![a100, a30]), 0.0);
    for (name, user, res) in [
        ("p-a100", "alice", "nvidia.com/mig-1g.5gb"),
        ("p-a30", "bob", "nvidia.com/mig-1g.6gb"),
    ] {
        s.create_pod(
            PodSpec::new(name, ResourceVec::cpu_millis(1000).with(res, 1), Payload::Sleep {
                duration: 3600.0,
            })
            .with_owner(user, "proj"),
            0.0,
        );
        s.bind(name, "n1", 0.0).unwrap();
        s.mark_running(name, 0.0).unwrap();
        s.finish_pod(name, PodPhase::Succeeded, 3600.0, "done").unwrap();
    }
    let r = account(&s, 3600.0);
    let a100_hours = r.by_user["alice"].mig_gpu_equiv_hours;
    let a30_hours = r.by_user["bob"].mig_gpu_equiv_hours;
    assert!((a100_hours - 1.0 / 7.0).abs() < 1e-9, "{a100_hours}");
    assert!((a30_hours - 1.0 / 4.0).abs() < 1e-9, "{a30_hours}");
    // parity: one slice-hour on each fills the same fraction of its device
    assert!(a30_hours > a100_hours, "an A30 slice is a larger GPU fraction");
}

/// Usage survives the PR-3 GC cascade: after a Workload deletion removes
/// the job's pods from the store, the accounting report is unchanged —
/// the ledger accrued at the terminal transition, not at report time.
#[test]
fn gc_cascade_preserves_accounting() {
    let mut api = common::api();
    let token = api.login("user004").unwrap();
    let created = api
        .create(
            &token,
            &ApiObject::BatchJob(BatchJobResource::request(
                "user004",
                "project02",
                ResourceVec::cpu_millis(4000).with(MEMORY, 8 << 30),
                600.0,
                PriorityClass::Batch,
                false,
            )),
        )
        .unwrap();
    let wl = created.name().to_string();
    api.run_for(1200.0, 10.0);
    assert_eq!(api.platform().workload_state(&wl), Some(WorkloadState::Finished));
    let before = api.platform().usage_report();
    let before_user = before.by_user["user004"];
    assert!(before_user.cpu_core_hours > 0.5, "{before_user:?}");
    assert_eq!(before_user.pods, 1);

    // delete the workload: the GC reconciler removes its pods entirely
    api.delete(&token, ResourceKind::Workload, &wl).unwrap();
    api.tick();
    let orphan_pods = {
        let st = api.platform().cluster();
        st.pods()
            .filter(|p| p.spec.labels.get("aiinfn/workload").map(String::as_str) == Some(&*wl))
            .count()
    };
    assert_eq!(orphan_pods, 0, "GC must have removed the job's pods");

    let after = api.platform().usage_report();
    assert_eq!(after.by_user["user004"], before_user, "usage must survive pod GC");
}

/// The fair-share tracker fills from the accounting ledger as jobs finish.
#[test]
fn fair_share_usage_accrues_from_completed_gpu_jobs() {
    let mut p = common::platform();
    let wl = p
        .submit_batch(
            "user009",
            "project01",
            ResourceVec::cpu_millis(1000).with("nvidia.com/mig-1g.5gb", 2),
            1800.0,
            PriorityClass::Batch,
            false,
        )
        .unwrap();
    p.run_for(3600.0, 10.0);
    assert_eq!(p.workload_state(&wl), Some(WorkloadState::Finished));
    let used = p.fair_share_usage("user009");
    assert!(used > 0.05, "2 slices × 0.5h ≈ 0.14 GPU-h of decayed usage, got {used}");
    assert_eq!(p.fair_share_usage("user010"), 0.0, "idle users carry no usage");
}

/// Chaos sweep invariant: at every tick of a faulty run with live
/// repartitioning in both directions (whole→MIG for slice demand,
/// MIG→whole for whole-GPU demand), every physical node's accelerator
/// advertisement equals the sum of its device layouts, modulo the units
/// chaos has currently degraded.
#[test]
fn chaos_sweep_extended_resources_match_device_layouts() {
    let seed = common::test_seed();
    let mut p = common::platform();
    let plan = ChaosPlan {
        seed,
        horizon: 3600.0,
        site_outages_per_hour: 0.5,
        wire_faults_per_hour: 1.0,
        remote_job_failures_per_hour: 0.5,
        node_flaps_per_hour: 0.3,
        node_down_duration: (60.0, 240.0),
        gpu_degrades_per_hour: 1.0,
        gpu_degrade_duration: (120.0, 600.0),
        ..Default::default()
    };
    p.install_chaos(&plan);

    let check_invariant = |p: &aiinfn::platform::Platform| {
        let st = p.cluster();
        for node in st.nodes() {
            if node.virtual_node {
                continue;
            }
            let mut expected = ResourceVec::new();
            for dev in &node.gpus {
                expected.add(&dev.extended_resources());
            }
            let mut keys: Vec<String> = expected.iter().map(|(k, _)| k.to_string()).collect();
            keys.extend(
                node.allocatable
                    .iter()
                    .filter(|(k, _)| k.starts_with("nvidia.com/") || k.starts_with("xilinx.com/"))
                    .map(|(k, _)| k.to_string()),
            );
            keys.sort();
            keys.dedup();
            for k in keys {
                let advertised = node.allocatable.get(&k) + p.degraded_units(&node.name, &k);
                assert_eq!(
                    advertised,
                    expected.get(&k),
                    "node {} resource {k}: allocatable+degraded != sum of device layouts",
                    node.name
                );
            }
        }
    };

    // phase 1: whole-GPU demand beyond the whole-GPU fleet (14 T4/RTX)
    // pulls idle A100s out of their MIG layouts
    let mut wls = Vec::new();
    for i in 0..16 {
        wls.push(
            p.submit_batch(
                &format!("user{:03}", i),
                "project06",
                ResourceVec::cpu_millis(2000).with(MEMORY, 8 << 30).with(GPU, 1),
                1800.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap(),
        );
    }
    let t1 = p.now() + 1800.0;
    while p.step_for(t1, 15.0) {
        check_invariant(&p);
    }

    // phase 2: a slice-demand wave pulls capacity back into MIG layouts
    for i in 0..40 {
        wls.push(
            p.submit_batch(
                &format!("user{:03}", 20 + i),
                "project06",
                ResourceVec::cpu_millis(1000)
                    .with(MEMORY, 4 << 30)
                    .with("nvidia.com/mig-1g.5gb", 1),
                300.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap(),
        );
    }
    let t2 = p.now() + 7200.0;
    while p.step_for(t2, 15.0) {
        check_invariant(&p);
    }

    assert!(p.metrics().repartitions >= 2, "{:?}", p.metrics());
    for w in &wls {
        assert_eq!(
            p.workload_state(w),
            Some(WorkloadState::Finished),
            "workload {w} lost under chaos: {:?}",
            p.metrics()
        );
    }
    // free index stayed exact through every repartition + fault
    p.cluster().check_free_index();
}
