//! Scheduling / queueing scenarios and cross-module property tests (split
//! out of the former monolithic `integration.rs`).

mod common;

use std::collections::HashSet;

use aiinfn::baseline::StaticVmFarm;
use aiinfn::cluster::pod::{Payload, PodPhase, PodSpec};
use aiinfn::cluster::resources::{ResourceVec, CPU};
use aiinfn::cluster::scheduler::Scheduler;
use aiinfn::cluster::store::ClusterStore;
use aiinfn::hub::profiles::default_catalogue;
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::sim::clock::hours;
use aiinfn::sim::trace::{generate, ArrivalKind, GpuDemand, TraceConfig};
use aiinfn::storage::backup::BackupRepo;
use aiinfn::util::prop::{forall, gens};
use aiinfn::util::rng::Rng;
use aiinfn::workflow::{parse_workflow, Dag};

// ---------------------------------------------------------------- scenarios

#[test]
fn full_day_campaign_is_deterministic() {
    let run = || {
        let mut p = common::platform();
        let trace = generate(&TraceConfig { seed: 123, ..Default::default() }, hours(24.0));
        let catalogue = default_catalogue();
        let mut ti = 0;
        while p.now() < hours(24.0) {
            let until = (p.now() + 300.0).min(hours(24.0));
            while ti < trace.len() && trace[ti].at <= until {
                let a = &trace[ti];
                ti += 1;
                match a.kind {
                    ArrivalKind::Interactive => {
                        let _ = p.spawn_session(&a.user, &catalogue[1]);
                    }
                    ArrivalKind::Batch => {
                        let _ =
                            p.submit_ml_training(&a.user, &a.project, a.duration * 5e12, a.gpu, true);
                    }
                }
            }
            p.run_for(until - p.now(), 60.0);
        }
        (
            p.pod_phase_counts().get("succeeded").copied().unwrap_or(0),
            p.metrics().evictions,
            p.metrics().offloaded_pods,
            p.tsdb.samples_ingested(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce the campaign exactly");
    assert!(a.0 > 0, "jobs must complete: {a:?}");
}

#[test]
fn capacity_is_conserved_through_a_churny_campaign() {
    let mut p = common::platform();
    let trace = generate(&TraceConfig { seed: 9, ..Default::default() }, hours(12.0));
    for a in &trace {
        // accelerator jobs only: CPU-only payloads at this FLOP count run
        // for O(100 h) under the cost model and would legitimately still be
        // running at the horizon.
        if a.kind == ArrivalKind::Batch && a.gpu != GpuDemand::None {
            let _ = p.submit_ml_training(&a.user, &a.project, a.duration * 1e13, a.gpu, false);
        }
    }
    p.run_for(hours(36.0), 30.0);
    // after everything drains, free == allocatable on every physical node
    let (used, _) = p.utilization(true);
    // some sessions may still linger but no batch jobs do; assert no leaked
    // accelerator reservations
    for (k, v) in used.iter() {
        if k.starts_with("nvidia.com/") {
            assert_eq!(v, 0, "leaked accelerator reservation on {k}");
        }
    }
    let (qused, _) = p.quota_utilization();
    assert!(qused.is_empty(), "leaked kueue quota: {qused}");
}

#[test]
fn hub_token_flows_through_object_store_mount() {
    let mut p = common::platform();
    let profile = default_catalogue().into_iter().find(|x| x.name == "cpu-small").unwrap();
    let sid = p.spawn_session("user042", &profile).unwrap();
    p.run_for(60.0, 10.0);
    let session = p.session(&sid).unwrap().clone();
    let mount = session.mount.expect("rclone mount established at spawn");
    // write through the mount, read back directly from the bucket
    let (auth, objects) = p.storage_mut();
    mount
        .write(auth, objects, "/home/user042/bucket/results/loss.json", b"{\"loss\":1.5}")
        .unwrap();
    let direct = objects.get("user042-bucket", "user042", "results/loss.json").unwrap();
    assert_eq!(direct, b"{\"loss\":1.5}");
}

#[test]
fn evicted_batch_job_finishes_after_interactive_leaves() {
    let mut p = common::platform();
    // fill all 35 MIG slices with long batch jobs
    let mut wls = Vec::new();
    for i in 0..35 {
        wls.push(
            p.submit_batch(
                &format!("user{:03}", i % 78),
                "project01",
                ResourceVec::cpu_millis(1000).with("nvidia.com/mig-1g.5gb", 1),
                4000.0,
                PriorityClass::Batch,
                false,
            )
            .unwrap(),
        );
    }
    p.run_for(120.0, 10.0);
    // an interactive user preempts one slice
    let profile = default_catalogue().into_iter().find(|x| x.name == "tensorflow-mig-1g").unwrap();
    let sid = p.spawn_session("user050", &profile).unwrap();
    p.run_for(300.0, 10.0);
    assert!(p.metrics().evictions >= 1, "a batch job must be evicted");
    // session leaves; evicted job must requeue, readmit, and finish
    p.stop_session(&sid, "done").unwrap();
    p.run_for(hours(4.0), 30.0);
    let finished = wls
        .iter()
        .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
        .count();
    assert_eq!(finished, 35, "every batch job must eventually finish");
}

#[test]
fn vm_baseline_loses_on_the_same_trace() {
    let trace = generate(&TraceConfig { seed: 31, ..Default::default() }, hours(7.0 * 24.0));
    let mut farm = StaticVmFarm::new(20);
    let vm = farm.replay(&trace);
    assert!(vm.refused > 0);
    assert!(vm.efficiency() < 0.6);
}

#[test]
fn trace_gpu_demand_distribution_matches_config() {
    let cfg = TraceConfig::default();
    let tr = generate(&cfg, hours(14.0 * 24.0));
    let inter: Vec<_> = tr.iter().filter(|a| a.kind == ArrivalKind::Interactive).collect();
    let gpu_frac =
        inter.iter().filter(|a| a.gpu != GpuDemand::None).count() as f64 / inter.len() as f64;
    assert!((gpu_frac - cfg.interactive_gpu_frac).abs() < 0.08, "{gpu_frac}");
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_scheduler_never_overcommits() {
    forall(
        "scheduler-no-overcommit",
        48,
        |rng: &mut Rng, b| {
            let n_nodes = 1 + rng.below(4) as usize;
            let pods: Vec<(i64, i64)> = (0..b.size * 4)
                .map(|_| (rng.range_i64(100, 16_000), rng.range_i64(0, 2)))
                .collect();
            (n_nodes, pods)
        },
        |(n_nodes, pods)| {
            let mut store = ClusterStore::new();
            for i in 0..*n_nodes {
                store.add_node(
                    aiinfn::cluster::node::Node::physical(
                        format!("n{i}"),
                        16,
                        64 << 30,
                        1 << 40,
                        vec![aiinfn::gpu::GpuDevice::whole(format!("g{i}"), aiinfn::gpu::GpuModel::TeslaT4)],
                    ),
                    0.0,
                );
            }
            for (i, (cpu, gpu)) in pods.iter().enumerate() {
                let mut req = ResourceVec::cpu_millis(*cpu);
                if *gpu > 0 {
                    req.set(aiinfn::cluster::resources::GPU, *gpu);
                }
                store.create_pod(
                    PodSpec::new(format!("p{i}"), req, Payload::Sleep { duration: 1.0 }),
                    0.0,
                );
            }
            let sched = Scheduler::default();
            sched.schedule_pending(&mut store, 0.0);
            // invariant: the incrementally-maintained free-capacity index
            // (the scheduler's candidate pruning) exactly mirrors the free
            // map after an arbitrary bind history
            store.check_free_index();
            // invariant: free >= 0 for every resource on every node, and
            // sum of scheduled requests <= allocatable
            for node in store.nodes().collect::<Vec<_>>() {
                let free = store.free_on(&node.name).unwrap();
                let mut reserved = ResourceVec::new();
                for p in store.pods() {
                    if p.status.node.as_deref() == Some(node.name.as_str())
                        && matches!(p.status.phase, PodPhase::Scheduled | PodPhase::Running)
                    {
                        reserved.add(&p.spec.requests);
                    }
                }
                if !reserved.plus(free).fits_in(&node.allocatable) {
                    return Err(format!("overcommit on {}: {} + {}", node.name, reserved, free));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_backup_roundtrip_any_bytes() {
    forall(
        "backup-roundtrip",
        32,
        |rng: &mut Rng, b| {
            let n_files = 1 + rng.below(4) as usize;
            (0..n_files)
                .map(|i| (format!("f{i}"), gens::bytes(rng, b.size * 4096)))
                .collect::<Vec<(String, Vec<u8>)>>()
        },
        |files| {
            let mut repo = BackupRepo::new("prop-pass");
            let (idx, _) =
                repo.create_snapshot("s", 0.0, files.iter().map(|(p, d)| (p.as_str(), d.as_slice())));
            for (path, data) in files {
                let back = repo.restore(idx, path).map_err(|e| e.to_string())?;
                if &back != data {
                    return Err(format!("restore mismatch for {path}: {} vs {}", back.len(), data.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dag_topo_order_respects_dependencies() {
    forall(
        "dag-topo-valid",
        32,
        |rng: &mut Rng, b| {
            // random linear pipelines with fan-out width
            let depth = 2 + rng.below(3) as usize;
            let samples = 1 + rng.below((b.size / 2 + 1) as u64) as usize;
            (depth, samples)
        },
        |(depth, samples)| {
            let mut rules = Vec::new();
            for d in 0..*depth {
                let input = if d == 0 {
                    "\"stage0/{s}.in\"".to_string()
                } else {
                    format!("\"stage{d}/{{s}}.dat\"")
                };
                rules.push(format!(
                    r#"{{"name": "r{d}", "input": [{input}], "output": ["stage{}/{{s}}.dat"], "duration": 10}}"#,
                    d + 1
                ));
            }
            let targets: Vec<String> =
                (0..*samples).map(|s| format!("\"stage{depth}/x{s}.dat\"")).collect();
            let wf = format!(r#"{{"rules": [{}], "targets": [{}]}}"#, rules.join(","), targets.join(","));
            let spec = parse_workflow(&wf).map_err(|e| e.to_string())?;
            let existing: HashSet<String> =
                (0..*samples).map(|s| format!("stage0/x{s}.in")).collect();
            let dag = Dag::build(&spec, &existing).map_err(|e| e.to_string())?;
            if dag.jobs.len() != depth * samples {
                return Err(format!("expected {} jobs, got {}", depth * samples, dag.jobs.len()));
            }
            let order = dag.topo_order();
            let mut pos = vec![0usize; dag.jobs.len()];
            for (i, &j) in order.iter().enumerate() {
                pos[j] = i;
            }
            for (j, deps) in dag.deps.iter().enumerate() {
                for &d in deps {
                    if pos[d] >= pos[j] {
                        return Err(format!("dependency {d} ordered after dependent {j}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_kueue_quota_conserved_under_random_churn() {
    forall(
        "kueue-quota-conservation",
        32,
        |rng: &mut Rng, b| {
            let ops: Vec<(u64, i64)> = (0..b.size * 2)
                .map(|_| (rng.below(3), rng.range_i64(100, 8000)))
                .collect();
            ops
        },
        |ops| {
            use aiinfn::queue::kueue::{ClusterQueue, Kueue, LocalQueue};
            let mut k = Kueue::new();
            k.add_cluster_queue(ClusterQueue {
                name: "cq".into(),
                cohort: None,
                nominal: ResourceVec::cpu_millis(20_000),
                used: ResourceVec::new(),
                can_borrow: false,
                can_lend: false,
            });
            k.add_local_queue(LocalQueue { name: "lq".into(), cluster_queue: "cq".into() });
            let mut live: Vec<String> = Vec::new();
            let mut t = 0.0;
            for (i, (op, cpu)) in ops.iter().enumerate() {
                t += 1.0;
                match op {
                    0 | 1 => {
                        let name = format!("w{i}");
                        k.submit(&name, "lq", PriorityClass::Batch, ResourceVec::cpu_millis(*cpu), t)
                            .map_err(|e| e.to_string())?;
                        live.push(name);
                        k.admit_pass(t);
                    }
                    _ => {
                        if let Some(name) = live.pop() {
                            k.finish(&name, t).map_err(|e| e.to_string())?;
                        }
                    }
                }
                // invariant: used <= nominal and used == sum of admitted
                let cq = k.cluster_queue("cq").unwrap();
                if !cq.used.fits_in(&cq.nominal) {
                    return Err(format!("quota exceeded: {} > {}", cq.used, cq.nominal));
                }
                let admitted_sum: i64 = k
                    .workloads()
                    .filter(|w| w.state == WorkloadState::Admitted)
                    .map(|w| w.requests.get(CPU))
                    .sum();
                if admitted_sum != cq.used.get(CPU) {
                    return Err(format!("used {} != admitted {}", cq.used.get(CPU), admitted_sum));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- PJRT e2e

#[test]
fn pjrt_training_through_runtime_when_artifacts_exist() {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let Ok(manifest) = aiinfn::runtime::Manifest::load(&dir) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut eng = aiinfn::runtime::Engine::cpu().unwrap();
    let mut tr = aiinfn::runtime::TrainRunner::new(&mut eng, &manifest, "tiny", false).unwrap();
    let (first, last) = tr.run(&mut eng, 40).unwrap();
    assert!(last < first - 0.5, "loss must fall: {first} → {last}");
    // inference with the trained weights beats inference with theta0
    let inf_trained =
        aiinfn::runtime::InferRunner::new(&mut eng, &manifest, "tiny", tr.theta().to_vec()).unwrap();
    let entry = manifest.model("tiny").unwrap();
    let tokens: Vec<i32> = manifest.load_corpus().unwrap()[..entry.batch * entry.seq].to_vec();
    let logits = inf_trained.logits(&mut eng, &tokens).unwrap();
    assert!(logits.iter().all(|v| v.is_finite()));
}
