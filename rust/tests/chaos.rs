//! Chaos + resilience scenario suite: deterministic golden traces,
//! randomized invariant sweeps, restart-budget semantics, and the
//! end-to-end self-healing acceptance scenario (SLURM-site blackout healed
//! through HTCondor capacity).

mod common;

use aiinfn::api::{ResourceKind, Selector};
use aiinfn::cluster::resources::{ResourceVec, GPU, MEMORY};
use aiinfn::offload::HealthStatus;
use aiinfn::platform::{Platform, RestartPolicy};
use aiinfn::queue::kueue::{PriorityClass, WorkloadState};
use aiinfn::sim::chaos::{ChaosEngine, ChaosPlan, Fault};
use aiinfn::sim::clock::hours;
use aiinfn::util::json::Json;

// ------------------------------------------------------------ golden trace

/// Run one full chaos scenario and render every transition the platform
/// recorded — chaos log, cluster events, Kueue workload transitions, site
/// health transitions — as one text blob.
fn chaos_trace(seed: u64) -> String {
    let mut p = common::platform();
    let plan = ChaosPlan {
        seed,
        horizon: 1200.0,
        site_outages_per_hour: 2.0,
        wire_faults_per_hour: 4.0,
        remote_job_failures_per_hour: 2.0,
        node_flaps_per_hour: 1.0,
        ..Default::default()
    };
    p.install_chaos(&plan);
    let _wls = common::submit_cpu_batch(&mut p, 20, 16_000, 400.0, true);
    p.run_for(3600.0, 15.0);

    let mut out = String::new();
    out.push_str(&p.chaos().unwrap().trace());
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    for t in p.health().transitions_since(0) {
        out.push_str(&format!(
            "{:10.3} HEALTH {} {} {}\n",
            t.at,
            t.site,
            t.status.as_str(),
            t.reason
        ));
    }
    out
}

/// Same seed ⇒ byte-identical event trace; different seed ⇒ different
/// trace. This is the determinism contract the whole scenario suite (and
/// CI's two-seed / two-thread-count matrix) rests on.
#[test]
fn golden_trace_same_seed_is_byte_identical() {
    let seed = common::test_seed();
    let a = chaos_trace(seed);
    let b = chaos_trace(seed);
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed must reproduce the transition log byte-for-byte");
    let c = chaos_trace(seed.wrapping_add(1));
    assert_ne!(a, c, "different chaos seeds must produce different traces");
}

// ------------------------------------------- crash-restore convergence

/// Store events + workload transitions + health transitions as one blob.
/// The chaos log is deliberately excluded: the crashed run legitimately
/// records the extra coordinator-crash entries.
fn durable_trace(seed: u64, crash: bool) -> (String, u64) {
    let mut cfg = common::config();
    cfg.durability_enabled = true;
    cfg.durability_snapshot_interval = 300.0;
    let mut p = Platform::bootstrap(cfg).unwrap();
    let plan = ChaosPlan {
        seed,
        horizon: 1200.0,
        site_outages_per_hour: 2.0,
        wire_faults_per_hour: 4.0,
        remote_job_failures_per_hour: 2.0,
        node_flaps_per_hour: 1.0,
        // drawn last in generate(): enabling kills leaves every other
        // seeded schedule byte-identical to the crash-free plan
        coordinator_crashes_per_hour: if crash { 6.0 } else { 0.0 },
        ..Default::default()
    };
    p.install_chaos(&plan);
    if crash {
        // pin one kill mid-campaign regardless of the Poisson draw
        p.chaos_mut().unwrap().inject(700.0, Fault::CoordinatorCrash { shard: None });
    }
    let _wls = common::submit_cpu_batch(&mut p, 20, 16_000, 400.0, true);
    p.run_for(3600.0, 15.0);

    let mut out = String::new();
    {
        let st = p.cluster();
        for ev in st.events() {
            out.push_str(&format!("{:10.3} {:?} {} {}\n", ev.at, ev.kind, ev.object, ev.message));
        }
    }
    for t in p.workload_transitions_since(0) {
        out.push_str(&format!("{:10.3} WORKLOAD {} {:?}\n", t.at, t.workload, t.state));
    }
    for t in p.health().transitions_since(0) {
        out.push_str(&format!(
            "{:10.3} HEALTH {} {} {}\n",
            t.at,
            t.site,
            t.status.as_str(),
            t.reason
        ));
    }
    (out, p.coordinator_restarts())
}

/// The durability acceptance criterion: a run whose coordinator is killed
/// mid-campaign and restored from snapshot + WAL converges to a
/// byte-identical transition log versus an uninterrupted run of the same
/// seed.
#[test]
fn crashed_and_restored_run_converges_to_uninterrupted_trace() {
    let seed = common::test_seed();
    let (clean, restarts_clean) = durable_trace(seed, false);
    let (crashed, restarts_crashed) = durable_trace(seed, true);
    assert_eq!(restarts_clean, 0);
    assert!(restarts_crashed >= 1, "the pinned kill must fire");
    assert!(!clean.is_empty());
    assert_eq!(
        clean, crashed,
        "a crashed-and-restored coordinator must converge to the uninterrupted \
         run's transition log"
    );
}

// ------------------------------------------------------ randomized sweeps

/// Across 100 random chaos schedules: no pod is lost (every submitted
/// workload ends Finished — succeeded or failed-with-exhausted-retries),
/// completion accounting balances exactly, Kueue quota drains to zero, and
/// watch resourceVersions stay strictly monotonic.
#[test]
fn random_chaos_schedules_preserve_invariants() {
    let base = common::test_seed();
    for i in 0..100u64 {
        let seed = base.wrapping_mul(1000).wrapping_add(i);
        let mut api = common::api();
        let plan = ChaosPlan {
            seed,
            horizon: 1800.0,
            site_outages_per_hour: 1.0,
            outage_duration: (120.0, 400.0),
            wire_faults_per_hour: 3.0,
            remote_job_failures_per_hour: 2.0,
            node_flaps_per_hour: 0.5,
            node_down_duration: (60.0, 240.0),
            gpu_degrades_per_hour: 0.5,
            gpu_degrade_duration: (120.0, 600.0),
            ..Default::default()
        };
        api.platform_mut().install_chaos(&plan);
        let n = 8usize;
        let wls: Vec<String> = (0..n)
            .map(|j| {
                api.platform_mut()
                    .submit_batch(
                        &format!("user{:03}", j % 78),
                        "project07",
                        ResourceVec::cpu_millis(8000).with(MEMORY, 8 << 30),
                        300.0,
                        PriorityClass::Batch,
                        j % 2 == 0,
                    )
                    .unwrap()
            })
            .collect();
        api.run_for(hours(3.0), 30.0);

        // (a) no pod lost: every workload reaches Finished
        for w in &wls {
            assert_eq!(
                api.platform().workload_state(w),
                Some(WorkloadState::Finished),
                "seed {seed}: workload {w} stuck: {:?}",
                api.platform().metrics()
            );
        }
        // (b) completion accounting balances exactly
        let m = api.platform().metrics();
        assert_eq!(
            m.local_completions + m.remote_completions + m.terminal_failures,
            n as u64,
            "seed {seed}: {m:?}"
        );
        // (c) Kueue quota fully drained
        let (used, _) = api.platform().quota_utilization();
        assert!(used.is_empty(), "seed {seed}: leaked quota {used}");
        // (d) watch resourceVersions strictly monotonic per kind
        let token = api.login("user000").unwrap();
        for kind in ResourceKind::all() {
            let evs = api.watch(&token, kind, 0).unwrap();
            for w in evs.windows(2) {
                assert!(
                    w[1].resource_version > w[0].resource_version,
                    "seed {seed}: rv regression in {kind:?} stream"
                );
            }
        }
        // (e) index consistency: the index-accelerated list equals the
        // brute-force serialize-and-filter result for every kind, across
        // label-Eq, label-absence, and field selectors
        for kind in ResourceKind::all() {
            for sel in [
                Selector::labels("app=batch").unwrap(),
                Selector::labels("ghost!=value").unwrap(),
                Selector::fields("status.phase=Running").unwrap(),
                Selector::parse("app in (batch,ml)", "spec.user!=user000").unwrap(),
                // unmodeled field path → the JSON-fallback/view-cache leg
                // (status.free moves without Node events, so this also
                // guards against stale cached serializations)
                Selector::fields("status.free.cpu!=0").unwrap(),
            ] {
                let indexed = api.list(&token, kind, &sel).unwrap();
                let brute: Vec<_> = api
                    .list(&token, kind, &Selector::all())
                    .unwrap()
                    .into_iter()
                    .filter(|o| sel.matches(&o.to_json()))
                    .collect();
                assert_eq!(
                    indexed, brute,
                    "seed {seed}: index-filtered list diverges from brute force \
                     for {kind:?} / {sel:?}"
                );
            }
        }
    }
}

// ------------------------------------------------------- restart budgets

/// RestartPolicy semantics: `Never` fails terminally on the first remote
/// failure; `OnFailure {{ max_retries: 1 }}` retries exactly once. In both
/// cases the workload still reaches Finished — nothing gets stuck.
#[test]
fn restart_budget_governs_terminal_failure() {
    let mut p = common::platform();
    // persistent killers on every site: any pod that shows up remotely is
    // failed on its next status sync
    let mut chaos = ChaosEngine::new();
    for site in ["INFN-T1", "ReCaS-Bari", "CINECA-Leonardo", "Podman-Edge"] {
        chaos.inject(50.0, Fault::RemoteJobFailures { site: site.into(), count: 5 });
    }
    p.set_chaos(chaos);
    // fill local capacity with long non-offloadable fillers so the victims
    // must offload (local allocatable ≈ 440 cores; 28 × 16 = 448)
    let fillers = common::submit_cpu_batch(&mut p, 28, 16_000, 3000.0, false);
    let never = p
        .submit_batch_with_policy(
            "user070",
            "project09",
            ResourceVec::cpu_millis(16_000).with(MEMORY, 16 << 30),
            600.0,
            PriorityClass::Batch,
            true,
            RestartPolicy::Never,
        )
        .unwrap();
    let once = p
        .submit_batch_with_policy(
            "user071",
            "project09",
            ResourceVec::cpu_millis(16_000).with(MEMORY, 16 << 30),
            600.0,
            PriorityClass::Batch,
            true,
            RestartPolicy::OnFailure { max_retries: 1 },
        )
        .unwrap();
    p.run_for(hours(3.0), 10.0);

    assert_eq!(p.workload_state(&never), Some(WorkloadState::Finished));
    assert_eq!(p.workload_state(&once), Some(WorkloadState::Finished));
    let m = p.metrics();
    assert_eq!(m.terminal_failures, 2, "{m:?}");
    assert_eq!(m.remote_retries, 1, "budget of 1 consumed exactly once: {m:?}");
    // the victims' pods failed: 1 (never) + 2 (once, retried) = 3
    assert_eq!(p.pod_phase_counts().get("failed"), Some(&3), "{:?}", p.pod_phase_counts());
    // one pending filler could not be placed while the cluster was full —
    // the failed placement was recorded, not discarded
    assert!(m.failed_placements >= 1, "{m:?}");
    // fillers themselves all drain eventually
    let done = fillers
        .iter()
        .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
        .count();
    assert_eq!(done, 28);
}

// ------------------------------------------------- acceptance: self-heal

/// The acceptance scenario: a SLURM-site (CINECA Leonardo) blackout
/// mid-run. The circuit breaker opens, affected workloads are requeued and
/// rescheduled — at least one onto an HTCondor site — the Site resource
/// shows a `Degraded → Healthy` transition over the watch stream, and the
/// run completes with zero terminally-failed pods.
#[test]
fn slurm_outage_heals_through_htcondor_end_to_end() {
    let mut api = common::api();
    let token = api.login("user001").unwrap();
    let rv0 = api.last_rv();

    let mut chaos = ChaosEngine::new();
    chaos.inject(300.0, Fault::SiteOutage { site: "CINECA-Leonardo".into() });
    chaos.inject(1600.0, Fault::SiteRecovery { site: "CINECA-Leonardo".into() });
    api.platform_mut().set_chaos(chaos);

    // nine 4-GPU jobs: three fit the local whole-GPU node (13 GPUs), the
    // federation takes the rest — two on INFN-T1 (HTCondor, 2×4 GPUs) and
    // four on CINECA Leonardo (SLURM, 4 nodes × 4 GPUs)
    let wls: Vec<String> = (0..9)
        .map(|i| {
            api.platform_mut()
                .submit_batch(
                    &format!("user{:03}", i),
                    "project03",
                    ResourceVec::cpu_millis(8000).with(MEMORY, 16 << 30).with(GPU, 4),
                    600.0,
                    PriorityClass::Batch,
                    true,
                )
                .unwrap()
        })
        .collect();
    api.run_for(2400.0, 10.0);

    // every workload healed; zero terminal failures
    for w in &wls {
        assert_eq!(api.platform().workload_state(w), Some(WorkloadState::Finished), "{w}");
    }
    let m = api.platform().metrics();
    assert_eq!(m.terminal_failures, 0, "{m:?}");
    assert!(m.breaker_trips >= 1, "the Leonardo breaker must open: {m:?}");
    assert!(m.failure_requeues >= 1, "outage victims must requeue: {m:?}");
    assert_eq!(
        api.platform().pod_phase_counts().get("failed"),
        None,
        "zero terminally-failed pods: {:?}",
        api.platform().pod_phase_counts()
    );

    // at least one requeued workload was rescheduled onto an HTCondor site
    let rerouted = {
        let st = api.platform().cluster();
        st.pods().any(|p| {
            p.spec.name.ends_with("-r2")
                && p.status.phase == aiinfn::cluster::pod::PodPhase::Succeeded
                && matches!(
                    p.status.node.as_deref(),
                    Some("vk-infn-t1") | Some("vk-recas-bari")
                )
        })
    };
    assert!(rerouted, "a rescheduled incarnation must succeed on an HTCondor site");

    // the Site watch stream shows Degraded → (Probing →) Healthy without
    // any polling of the resource
    let health_seq: Vec<String> = api
        .watch(&token, ResourceKind::Site, rv0)
        .unwrap()
        .into_iter()
        .filter(|e| e.name == "CINECA-Leonardo")
        .filter_map(|e| {
            e.object
                .as_ref()
                .and_then(|o| o.at(&["status", "health"]))
                .and_then(Json::as_str)
                .map(String::from)
        })
        .collect();
    let degraded = health_seq.iter().position(|s| s == "Degraded");
    let healthy = health_seq.iter().rposition(|s| s == "Healthy");
    assert!(
        matches!((degraded, healthy), (Some(d), Some(h)) if d < h),
        "watch must observe Degraded before Healthy: {health_seq:?}"
    );
    // and the breaker is closed at the end
    assert_eq!(api.platform().site_health("CINECA-Leonardo"), HealthStatus::Healthy);
}
