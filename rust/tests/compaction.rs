//! Log-compaction suite: ring-log memory bounds under a long chaos soak,
//! the "410 Gone" relist contract for watchers that fall behind, and
//! cursor-pump equivalence across compaction boundaries (a tightly
//! compacted control plane converges to the same outcome as an unbounded
//! one, because every consumer reads deltas through absolute cursors).

mod common;

use aiinfn::api::{ApiError, ApiServer, ResourceKind, Selector};
use aiinfn::platform::Platform;
use aiinfn::queue::kueue::WorkloadState;
use aiinfn::sim::chaos::ChaosPlan;

/// A platform with a deliberately tiny compaction window, so rings wrap
/// many times within a normal test run.
fn api_with_window(window: usize) -> ApiServer {
    let mut cfg = common::config();
    cfg.compaction_window = window;
    ApiServer::new(Platform::bootstrap(cfg).unwrap())
}

/// A watcher that falls behind the retained window gets the typed
/// `Compacted` error (410 Gone) and recovers by re-listing and resuming
/// from `last_rv()` — the Kubernetes relist contract.
#[test]
fn stale_watcher_gets_compacted_and_relists() {
    let mut api = api_with_window(64);
    let token = api.login("user001").unwrap();
    let rv0 = api.last_rv();

    // enough pod churn to wrap the 64-event Pod stream several times
    common::submit_cpu_batch(api.platform_mut(), 40, 4_000, 60.0, false);
    api.run_for(3600.0, 15.0);

    let err = api.watch(&token, ResourceKind::Pod, rv0).unwrap_err();
    assert!(
        matches!(err, ApiError::Compacted(_)),
        "a watcher behind the window must see 410 Gone, got {err:?}"
    );

    // relist: the list verb serves current state regardless of the log…
    let pods = api.list(&token, ResourceKind::Pod, &Selector::all()).unwrap();
    assert!(!pods.is_empty(), "relist must return current state");
    // …and watching from last_rv resumes cleanly
    let resume = api.last_rv();
    assert!(api.watch(&token, ResourceKind::Pod, resume).unwrap().is_empty());
    api.run_for(60.0, 15.0);
    for ev in api.watch(&token, ResourceKind::Pod, resume).unwrap() {
        assert!(ev.resource_version > resume);
    }
}

/// The 10k-tick chaos soak: every control-plane log — store events, Kueue
/// and health transitions, each watch stream — stays within the configured
/// ring capacity while the platform keeps converging. The absolute
/// cursors prove compaction actually happened (entries ever >> retained).
#[test]
fn chaos_soak_10k_ticks_stays_within_ring_capacity() {
    let window = 64usize;
    let mut api = api_with_window(window);
    let plan = ChaosPlan {
        seed: common::test_seed(),
        horizon: 150_000.0,
        site_outages_per_hour: 0.5,
        wire_faults_per_hour: 2.0,
        remote_job_failures_per_hour: 1.0,
        node_flaps_per_hour: 4.0,
        gpu_degrades_per_hour: 1.0,
        ..Default::default()
    };
    api.platform_mut().install_chaos(&plan);
    let wls = common::submit_cpu_batch(api.platform_mut(), 12, 8_000, 400.0, true);

    // 10 000 ticks of 15 s ≈ 41 simulated hours under continuous faults
    api.run_for(150_000.0, 15.0);

    let p = api.platform();
    {
        let st = p.cluster();
        assert!(
            st.events().len() <= window,
            "store event ring exceeded its window: {} > {window}",
            st.events().len()
        );
        assert!(
            st.event_cursor() > 10 * window,
            "the soak must actually wrap the event ring (cursor {})",
            st.event_cursor()
        );
    }
    assert!(p.kueue_transition_log_len() <= window, "kueue ring exceeded its window");
    assert!(p.health_transition_log_len() <= window, "health ring exceeded its window");
    // the watch log holds at most `window` events per kind
    assert!(
        api.watch_log_len() <= window * ResourceKind::all().len(),
        "watch log exceeded its per-kind windows: {}",
        api.watch_log_len()
    );

    // compaction must not have cost correctness: everything converged
    for w in &wls {
        assert_eq!(
            api.platform().workload_state(w),
            Some(WorkloadState::Finished),
            "workload {w} stuck under a compacted control plane"
        );
    }
}

/// Cursor pumps across compaction boundaries lose nothing: the identical
/// scenario run with a tiny window and an effectively unbounded one ends
/// in the same place — same workload outcomes, same completion
/// accounting, same pod phase census.
#[test]
fn tiny_window_run_matches_unbounded_run() {
    let outcome = |window: usize| {
        let mut api = api_with_window(window);
        let plan = ChaosPlan {
            seed: common::test_seed(),
            horizon: 3_600.0,
            site_outages_per_hour: 1.0,
            wire_faults_per_hour: 3.0,
            remote_job_failures_per_hour: 2.0,
            node_flaps_per_hour: 1.0,
            ..Default::default()
        };
        api.platform_mut().install_chaos(&plan);
        let wls = common::submit_cpu_batch(api.platform_mut(), 16, 8_000, 300.0, true);
        api.run_for(7_200.0, 15.0);
        let p = api.platform();
        let states: Vec<_> = wls.iter().map(|w| p.workload_state(w)).collect();
        let m = p.metrics();
        (
            states,
            m.local_completions,
            m.remote_completions,
            m.terminal_failures,
            m.evictions,
            p.pod_phase_counts(),
        )
    };
    let tiny = outcome(96);
    let unbounded = outcome(1_000_000);
    assert_eq!(
        tiny, unbounded,
        "a compacted control plane must converge exactly like an unbounded one"
    );
}
