//! Federation / offloading integration tests (split out of the former
//! monolithic `integration.rs`): InterLink wire traffic at campaign scale,
//! plus transient wire-fault tolerance below the breaker threshold.

mod common;

use aiinfn::hub::profiles::default_catalogue;
use aiinfn::offload::HealthStatus;
use aiinfn::queue::kueue::WorkloadState;
use aiinfn::sim::chaos::{ChaosEngine, Fault};
use aiinfn::sim::clock::hours;

#[test]
fn submit_cpu_heavy_campaign_drains_via_federation() {
    let mut p = common::platform();
    let wls = common::submit_cpu_batch(&mut p, 80, 24_000, 900.0, true);
    p.run_for(hours(8.0), 20.0);
    let finished = wls
        .iter()
        .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
        .count();
    assert_eq!(finished, 80);
    assert!(p.metrics().remote_completions > 0, "{:?}", p.metrics());
    // InterLink wire must have been exercised
    let rt = p.interlink_round_trips();
    assert!(rt > 100, "expected many InterLink round-trips, got {rt}");
    // interactive demand arriving *after* the storm still gets placed fast
    let profile = default_catalogue().into_iter().find(|x| x.name == "tensorflow-mig-1g").unwrap();
    p.spawn_session("user077", &profile).unwrap();
    p.run_for(120.0, 5.0);
    let lat = p.metrics().interactive_spawn_latencies.last().copied().unwrap();
    assert!(lat < 60.0, "spawn latency {lat}");
}

/// A short burst of wire timeouts (below the breaker threshold) must not
/// quarantine the site: the affected workloads requeue and the campaign
/// still drains with the site Healthy.
#[test]
fn transient_wire_faults_tolerated_without_quarantine() {
    let mut p = common::platform();
    let mut chaos = ChaosEngine::new();
    // two timeouts: below the 3-consecutive-failure threshold, and the next
    // successful sync resets the consecutive count
    chaos.inject(40.0, Fault::WireTimeouts { site: "INFN-T1".into(), count: 2 });
    p.set_chaos(chaos);
    let wls = common::submit_cpu_batch(&mut p, 40, 16_000, 300.0, true);
    p.run_for(hours(2.0), 10.0);
    let finished = wls
        .iter()
        .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
        .count();
    assert_eq!(finished, 40, "{:?}", p.metrics());
    assert_eq!(p.metrics().breaker_trips, 0, "{:?}", p.metrics());
    assert_eq!(p.site_health("INFN-T1"), HealthStatus::Healthy);
}

/// Dropped InterLink responses leave orphan remote jobs but never lose the
/// workload: the create is retried (wire drop → requeue) and every job
/// finishes.
#[test]
fn dropped_responses_requeue_instead_of_failing() {
    let mut p = common::platform();
    let mut chaos = ChaosEngine::new();
    // active from the very first tick, so the first InterLink creates to
    // INFN-T1 lose their responses
    chaos.inject(5.0, Fault::WireDrops { site: "INFN-T1".into(), count: 2 });
    p.set_chaos(chaos);
    let wls = common::submit_cpu_batch(&mut p, 40, 16_000, 300.0, true);
    p.run_for(hours(2.0), 10.0);
    let finished = wls
        .iter()
        .filter(|w| p.workload_state(w) == Some(WorkloadState::Finished))
        .count();
    assert_eq!(finished, 40, "{:?}", p.metrics());
    assert!(p.metrics().failure_requeues >= 1, "{:?}", p.metrics());
    assert_eq!(p.metrics().terminal_failures, 0, "{:?}", p.metrics());
    assert_eq!(p.pod_phase_counts().get("failed"), None);
}
